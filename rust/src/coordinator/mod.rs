//! L3 coordinator — the run-time owner of the reduction.
//!
//! Owns the banded buffer, computes the stage plan, steps the launch
//! loop (with the paper's 3-cycle schedule), batches tasks under the
//! MaxBlocks capacity, dispatches to a backend, and collects metrics.
//! Backends:
//!
//! - [`Backend::Sequential`] / [`Backend::Parallel`] — native Rust cycle
//!   kernels (any precision).
//! - [`Backend::Pjrt`] — per-launch AOT artifacts through the PJRT CPU
//!   client (f32; python never runs — artifacts are pre-compiled).
//! - [`Backend::PjrtFused`] — whole-stage artifacts, one call per stage.

pub mod metrics;

use crate::banded::storage::Banded;
use crate::batch::engine::{run_interleaved, Runner};
use crate::bulge::cycle::{exec_cycle, CycleWorkspace};
use crate::bulge::schedule::{stage_plan, TaskStream};
use crate::config::{Backend, PackingPolicy, TuneParams};
use crate::error::{Error, Result};
use crate::runtime::PjrtEngine;
use crate::scalar::Scalar;
use crate::util::threadpool::ThreadPool;
use metrics::LaunchMetrics;
use std::time::Instant;

/// Result of a coordinated reduction.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub backend: Backend,
    pub n: usize,
    pub bw: usize,
    pub params: TuneParams,
    pub metrics: LaunchMetrics,
    pub diag: Vec<f64>,
    pub superdiag: Vec<f64>,
    /// Largest |element| outside the bidiagonal after the run (0 when
    /// fully reduced; small ≠ 0 through the f32 PJRT path).
    pub residual_off_band: f64,
}

/// The coordinator: tuning parameters + worker pool.
pub struct Coordinator {
    pub params: TuneParams,
    pool: ThreadPool,
}

impl Coordinator {
    pub fn new(params: TuneParams, threads: usize) -> Self {
        Self { params, pool: ThreadPool::new(threads) }
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Block capacity per launch: MaxBlocks tasks run concurrently; the
    /// rest are loop-unrolled inside workers (the CPU stand-in for the
    /// paper's per-execution-unit limit).
    fn capacity(&self) -> usize {
        self.params.max_blocks.max(1)
    }

    /// Run a native reduction (sequential or thread-pooled launch loop).
    pub fn reduce_native<T: Scalar>(
        &self,
        a: &mut Banded<T>,
        bw: usize,
        backend: Backend,
    ) -> Result<RunReport> {
        let n = a.n();
        let tw = self.params.effective_tw(bw);
        a.check_reduction_storage(bw, tw)?;
        let mut m = LaunchMetrics::default();
        let capacity = self.capacity();
        let t_start = Instant::now();
        match backend {
            Backend::Sequential => {
                // The launch stream in schedule order, executed inline
                // (one task at a time, empty launches skipped).
                let plan = stage_plan(bw, tw);
                let mut ws = CycleWorkspace::for_plan(&plan);
                let mut stream = TaskStream::new(plan, n);
                while let Some((si, tasks)) = stream.next_launch() {
                    m.record_launch(tasks.len(), capacity);
                    let stage = stream.plan()[si];
                    for task in &tasks {
                        exec_cycle(a, &stage, task, &mut ws);
                    }
                }
            }
            Backend::Parallel => {
                // The batch-size-1 case of the interleaved batch engine
                // (crate::batch): one runner, one stream, same launch
                // loop the multi-problem path uses.
                let mut runners = vec![Runner::new(a, bw, &self.params)?];
                run_interleaved(&mut runners, &self.pool, capacity, PackingPolicy::RoundRobin, 1);
                m = runners[0].metrics.clone();
            }
            other => {
                return Err(Error::Config(format!(
                    "reduce_native cannot run backend {other:?}; use reduce_pjrt"
                )))
            }
        }
        m.wall = t_start.elapsed();
        let (diag, superdiag) = a.bidiagonal();
        Ok(RunReport {
            backend,
            n,
            bw,
            params: self.params,
            metrics: m,
            diag: diag.iter().map(|v| v.to_f64()).collect(),
            superdiag: superdiag.iter().map(|v| v.to_f64()).collect(),
            residual_off_band: a.max_off_band(1),
        })
    }

    /// Run the reduction through pre-compiled PJRT artifacts.
    pub fn reduce_pjrt<T: Scalar>(
        &self,
        engine: &PjrtEngine,
        a: &mut Banded<T>,
        backend: Backend,
    ) -> Result<RunReport> {
        let fused = match backend {
            Backend::Pjrt => false,
            Backend::PjrtFused => true,
            other => {
                return Err(Error::Config(format!(
                    "reduce_pjrt cannot run backend {other:?}"
                )))
            }
        };
        let n = a.n();
        let bw = engine.manifest().bw;
        let capacity = self.capacity();
        let mut m = LaunchMetrics::default();
        let t_start = Instant::now();
        if fused {
            engine.reduce_banded(a, true)?;
            // Launch metrics reconstructed from the schedule (the fused
            // artifact runs the same launches inside one call).
            for st in &engine.manifest().stages {
                let stage = crate::bulge::schedule::Stage::new(st.b, st.d);
                for t in 0..st.launches {
                    m.record_launch(stage.tasks_at_count(n, t), capacity);
                }
            }
        } else {
            // Per-cycle path: count real launches as they execute.
            let manifest = engine.manifest().clone();
            let mut flat = a.to_f32_flat();
            engine.reduce_per_cycle(&mut flat, |si, t| {
                let st = &manifest.stages[si];
                let stage = crate::bulge::schedule::Stage::new(st.b, st.d);
                m.record_launch(stage.tasks_at_count(n, t), capacity);
            })?;
            a.from_f32_flat(&flat);
        }
        m.wall = t_start.elapsed();
        let (diag, superdiag) = a.bidiagonal();
        Ok(RunReport {
            backend,
            n,
            bw,
            params: self.params,
            metrics: m,
            diag: diag.iter().map(|v| v.to_f64()).collect(),
            superdiag: superdiag.iter().map(|v| v.to_f64()).collect(),
            residual_off_band: a.max_off_band(1),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn native_backends_agree_and_report_metrics() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 8 };
        let coord = Coordinator::new(params, 4);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (n, bw) = (64, 8);
        let mut a1 = random_banded::<f64>(n, bw, 4, &mut rng);
        let mut a2 = a1.clone();
        let r1 = coord.reduce_native(&mut a1, bw, Backend::Sequential).unwrap();
        let r2 = coord.reduce_native(&mut a2, bw, Backend::Parallel).unwrap();
        assert_eq!(a1, a2);
        assert_eq!(r1.metrics.launches, r2.metrics.launches);
        assert_eq!(r1.metrics.tasks, r2.metrics.tasks);
        assert_eq!(r1.residual_off_band, 0.0);
        assert!(r1.metrics.max_parallel >= 1);
        assert!(r1.metrics.avg_parallel() > 0.0);
    }

    #[test]
    fn unrolling_is_detected_when_capacity_small() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 1 };
        let coord = Coordinator::new(params, 2);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (n, bw) = (96, 8);
        let mut a = random_banded::<f64>(n, bw, 4, &mut rng);
        let r = coord.reduce_native(&mut a, bw, Backend::Parallel).unwrap();
        assert!(r.metrics.unrolled_launches > 0);
    }

    #[test]
    fn storage_too_small_is_rejected() {
        let params = TuneParams { tpb: 32, tw: 8, max_blocks: 8 };
        let coord = Coordinator::new(params, 1);
        let mut a = Banded::<f64>::zeros(32, 9, 1); // kd_sub 1 < tw 8
        assert!(coord.reduce_native(&mut a, 8, Backend::Sequential).is_err());
    }

    #[test]
    fn pjrt_backend_through_native_entry_is_rejected() {
        let params = TuneParams::default();
        let coord = Coordinator::new(params, 1);
        let mut a = Banded::<f64>::for_reduction(16, 2, 1);
        assert!(coord.reduce_native(&mut a, 2, Backend::Pjrt).is_err());
    }
}
