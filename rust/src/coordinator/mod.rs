//! L3 coordinator — the run-time owner of the reduction.
//!
//! Owns the banded buffer, lowers the 3-cycle schedule into a
//! [`LaunchPlan`] (the same value the simulator costs —
//! `simulator::model::simulate_plan` — so predicted launches/occupancy
//! are exact by construction), executes it, and collects metrics.
//! Backends:
//!
//! - [`Backend::Sequential`] / [`Backend::Parallel`] — native Rust cycle
//!   kernels (any precision), in-place or packed-tile per stage width.
//! - [`Backend::Pjrt`] — per-launch AOT artifacts through the PJRT CPU
//!   client (f32; python never runs — artifacts are pre-compiled).
//! - [`Backend::PjrtFused`] — whole-stage artifacts, one call per stage.

pub mod metrics;

use crate::banded::storage::Banded;
use crate::batch::engine::{execute_plan, Runner};
use crate::bulge::cycle::{exec_cycle, CycleWorkspace};
use crate::bulge::schedule::CycleTask;
use crate::config::{Backend, TuneParams};
use crate::error::{Error, Result};
use crate::plan::{slot_bytes, LaunchPlan};
use crate::runtime::PjrtEngine;
use crate::scalar::Scalar;
use crate::util::threadpool::ThreadPool;
use metrics::LaunchMetrics;
use std::time::Instant;

/// Result of a coordinated reduction.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub backend: Backend,
    pub n: usize,
    pub bw: usize,
    pub params: TuneParams,
    pub metrics: LaunchMetrics,
    pub diag: Vec<f64>,
    pub superdiag: Vec<f64>,
    /// Largest |element| outside the bidiagonal after the run (0 when
    /// fully reduced; small ≠ 0 through the f32 PJRT path).
    pub residual_off_band: f64,
}

/// The coordinator: tuning parameters + worker pool.
pub struct Coordinator {
    pub params: TuneParams,
    pool: ThreadPool,
}

impl Coordinator {
    pub fn new(params: TuneParams, threads: usize) -> Self {
        Self { params, pool: ThreadPool::new(threads) }
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// The launch plan this coordinator executes for an `n × n` problem of
    /// bandwidth `bw` — the identical value
    /// [`crate::simulator::model::simulate_reduction`] costs for the same
    /// `(n, bw, TuneParams)`.
    pub fn launch_plan(&self, n: usize, bw: usize) -> LaunchPlan {
        LaunchPlan::for_problem(n, bw, &self.params)
    }

    /// Run a native reduction (sequential or thread-pooled launch loop).
    pub fn reduce_native<T: Scalar>(
        &self,
        a: &mut Banded<T>,
        bw: usize,
        backend: Backend,
    ) -> Result<RunReport> {
        let n = a.n();
        let tw = self.params.effective_tw(bw);
        a.check_reduction_storage(bw, tw)?;
        let plan = self.launch_plan(n, bw);
        let capacity = plan.capacity;
        let es = T::BYTES;
        let mut m = LaunchMetrics::default();
        let t_start = Instant::now();
        match backend {
            Backend::Sequential => {
                // The plan executed inline, one task at a time, in launch
                // order (the schedule-order oracle path).
                let mut ws = CycleWorkspace::for_plan(&plan);
                let mut tasks: Vec<CycleTask> = Vec::new();
                for li in 0..plan.num_launches() {
                    m.record_launch(plan.launch_tasks(li), capacity, plan.launch_bytes(li, es));
                    for slot in plan.launch(li) {
                        let stage = *plan.slot_stage(slot);
                        tasks.clear();
                        stage.tasks_at_into(n, slot.t as usize, &mut tasks);
                        for task in &tasks {
                            exec_cycle(a, &stage, task, &mut ws);
                        }
                    }
                }
            }
            Backend::Parallel => {
                // The batch-size-1 case of the plan executor
                // (crate::batch): one runner, the same launch loop the
                // multi-problem path uses.
                let mut runners = vec![Runner::new(a, &plan)?];
                execute_plan(&plan, &mut runners, &self.pool);
                m = runners[0].metrics.clone();
            }
            other => {
                return Err(Error::Config(format!(
                    "reduce_native cannot run backend {other:?}; use reduce_pjrt"
                )))
            }
        }
        m.wall = t_start.elapsed();
        let (diag, superdiag) = a.bidiagonal();
        Ok(RunReport {
            backend,
            n,
            bw,
            params: self.params,
            metrics: m,
            diag: diag.iter().map(|v| v.to_f64()).collect(),
            superdiag: superdiag.iter().map(|v| v.to_f64()).collect(),
            residual_off_band: a.max_off_band(1),
        })
    }

    /// Run the reduction through pre-compiled PJRT artifacts.
    pub fn reduce_pjrt<T: Scalar>(
        &self,
        engine: &PjrtEngine,
        a: &mut Banded<T>,
        backend: Backend,
    ) -> Result<RunReport> {
        let fused = match backend {
            Backend::Pjrt => false,
            Backend::PjrtFused => true,
            other => {
                return Err(Error::Config(format!(
                    "reduce_pjrt cannot run backend {other:?}"
                )))
            }
        };
        let n = a.n();
        let bw = engine.manifest().bw;
        let capacity = self.params.capacity();
        // Artifacts execute in f32 regardless of the in-memory precision.
        let es = 4;
        let mut m = LaunchMetrics::default();
        let t_start = Instant::now();
        if fused {
            engine.reduce_banded(a, true)?;
            // Launch metrics reconstructed from the schedule (the fused
            // artifact runs the same launches inside one call).
            for st in &engine.manifest().stages {
                let stage = crate::bulge::schedule::Stage::new(st.b, st.d);
                for t in 0..st.launches {
                    let count = stage.tasks_at_count(n, t);
                    m.record_launch(count, capacity, slot_bytes(&stage, count, es));
                }
            }
        } else {
            // Per-cycle path: count real launches as they execute.
            let manifest = engine.manifest().clone();
            let mut flat = a.to_f32_flat();
            engine.reduce_per_cycle(&mut flat, |si, t| {
                let st = &manifest.stages[si];
                let stage = crate::bulge::schedule::Stage::new(st.b, st.d);
                let count = stage.tasks_at_count(n, t);
                m.record_launch(count, capacity, slot_bytes(&stage, count, es));
            })?;
            a.from_f32_flat(&flat);
        }
        m.wall = t_start.elapsed();
        let (diag, superdiag) = a.bidiagonal();
        Ok(RunReport {
            backend,
            n,
            bw,
            params: self.params,
            metrics: m,
            diag: diag.iter().map(|v| v.to_f64()).collect(),
            superdiag: superdiag.iter().map(|v| v.to_f64()).collect(),
            residual_off_band: a.max_off_band(1),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn native_backends_agree_and_report_metrics() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 8 };
        let coord = Coordinator::new(params, 4);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (n, bw) = (64, 8);
        let mut a1 = random_banded::<f64>(n, bw, 4, &mut rng);
        let mut a2 = a1.clone();
        let r1 = coord.reduce_native(&mut a1, bw, Backend::Sequential).unwrap();
        let r2 = coord.reduce_native(&mut a2, bw, Backend::Parallel).unwrap();
        assert_eq!(a1, a2);
        assert_eq!(r1.metrics.launches, r2.metrics.launches);
        assert_eq!(r1.metrics.tasks, r2.metrics.tasks);
        assert_eq!(r1.metrics.per_launch, r2.metrics.per_launch);
        assert_eq!(r1.metrics.bytes, r2.metrics.bytes);
        assert_eq!(r1.residual_off_band, 0.0);
        assert!(r1.metrics.max_parallel >= 1);
        assert!(r1.metrics.avg_parallel() > 0.0);
    }

    #[test]
    fn metrics_match_the_launch_plan_exactly() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 8 };
        let coord = Coordinator::new(params, 2);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (n, bw) = (72, 9);
        let plan = coord.launch_plan(n, bw);
        let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let r = coord.reduce_native(&mut a, bw, Backend::Parallel).unwrap();
        assert_eq!(r.metrics.launches, plan.num_launches());
        assert_eq!(r.metrics.tasks, plan.total_tasks());
        for (li, &got) in r.metrics.per_launch.iter().enumerate() {
            assert_eq!(got as usize, plan.launch_tasks(li), "launch {li}");
        }
    }

    #[test]
    fn unrolling_is_detected_when_capacity_small() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 1 };
        let coord = Coordinator::new(params, 2);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (n, bw) = (96, 8);
        let mut a = random_banded::<f64>(n, bw, 4, &mut rng);
        let r = coord.reduce_native(&mut a, bw, Backend::Parallel).unwrap();
        assert!(r.metrics.unrolled_launches > 0);
    }

    #[test]
    fn storage_too_small_is_rejected() {
        let params = TuneParams { tpb: 32, tw: 8, max_blocks: 8 };
        let coord = Coordinator::new(params, 1);
        let mut a = Banded::<f64>::zeros(32, 9, 1); // kd_sub 1 < tw 8
        assert!(coord.reduce_native(&mut a, 8, Backend::Sequential).is_err());
    }

    #[test]
    fn pjrt_backend_through_native_entry_is_rejected() {
        let params = TuneParams::default();
        let coord = Coordinator::new(params, 1);
        let mut a = Banded::<f64>::for_reduction(16, 2, 1);
        assert!(coord.reduce_native(&mut a, 2, Backend::Pjrt).is_err());
    }
}
