//! L3 coordinator — the run-time owner of a single-problem reduction.
//!
//! Lowers the 3-cycle schedule into a [`LaunchPlan`] (the same value the
//! simulator costs — `simulator::model::simulate_plan` — so predicted
//! launches/occupancy are exact by construction) and hands it to a
//! [`Backend`] for execution; metrics come back per launch. Backend
//! selection goes through [`BackendKind`]:
//!
//! - [`BackendKind::Sequential`] / [`BackendKind::Threadpool`] — native
//!   Rust cycle kernels (any precision), executed by
//!   [`crate::backend::SequentialBackend`] /
//!   [`crate::backend::ThreadpoolBackend`].
//! - [`BackendKind::Simd`] — the same launch loop over the coordinator's
//!   resident pool, with packed-path tasks routed through the
//!   [`crate::simd`] vector kernels
//!   ([`crate::backend::SimdBackend::borrowing`]).
//! - [`BackendKind::Pjrt`] — the plan-driven PJRT executor
//!   ([`crate::backend::PjrtBackend`]): per-launch AOT artifacts, one
//!   device-resident buffer, f32.
//! - [`BackendKind::PjrtFused`] — whole-stage artifacts, one PJRT call
//!   per stage; metrics still derive from the plan the stages fuse.

pub mod metrics;

use crate::backend::{
    execute_reduction, pjrt::execute_plan_on_engine, AsBandStorageMut, Backend, SequentialBackend,
    SimdBackend, ThreadpoolBackend,
};
use crate::banded::storage::Banded;
use crate::config::{BackendKind, TuneParams};
use crate::error::{Error, Result};
use crate::plan::LaunchPlan;
use crate::runtime::PjrtEngine;
use crate::scalar::Scalar;
use crate::util::threadpool::ThreadPool;
use metrics::LaunchMetrics;
use std::time::Instant;

/// Result of a coordinated reduction.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub backend: BackendKind,
    pub n: usize,
    pub bw: usize,
    pub params: TuneParams,
    pub metrics: LaunchMetrics,
    pub diag: Vec<f64>,
    pub superdiag: Vec<f64>,
    /// Largest |element| outside the bidiagonal after the run (0 when
    /// fully reduced; small ≠ 0 through the f32 PJRT path).
    pub residual_off_band: f64,
}

/// The coordinator: tuning parameters + the resident threadpool backend
/// (other backends are constructed per call or passed in explicitly via
/// [`Coordinator::reduce_with`]).
pub struct Coordinator {
    pub params: TuneParams,
    threadpool: ThreadpoolBackend<'static>,
}

impl Coordinator {
    pub fn new(params: TuneParams, threads: usize) -> Self {
        Self { params, threadpool: ThreadpoolBackend::new(threads) }
    }

    pub fn pool(&self) -> &ThreadPool {
        self.threadpool.pool()
    }

    /// The launch plan this coordinator executes for an `n × n` problem of
    /// bandwidth `bw` — the identical value
    /// [`crate::simulator::model::simulate_reduction`] costs for the same
    /// `(n, bw, TuneParams)`.
    pub fn launch_plan(&self, n: usize, bw: usize) -> LaunchPlan {
        LaunchPlan::for_problem(n, bw, &self.params)
    }

    /// Run the reduction on an explicit [`Backend`] trait object — the
    /// fully general entry point every kind-specific method funnels into
    /// (validation + lowering + execution live in
    /// [`crate::backend::execute_reduction`], shared with the pipeline).
    pub fn reduce_with<T: Scalar>(
        &self,
        backend: &dyn Backend,
        a: &mut Banded<T>,
        bw: usize,
    ) -> Result<RunReport>
    where
        Banded<T>: AsBandStorageMut,
    {
        let n = a.n();
        let kind = backend.kind();
        let t_start = Instant::now();
        let (_plan, exec) = execute_reduction(backend, a, bw, &self.params)?;
        let mut m = exec.per_problem.into_iter().next().unwrap_or_default();
        m.wall = t_start.elapsed();
        Ok(Self::report(kind, n, bw, self.params, m, a))
    }

    /// Run a native reduction (inline sequential or thread-pooled launch
    /// loop) selected by kind.
    pub fn reduce_native<T: Scalar>(
        &self,
        a: &mut Banded<T>,
        bw: usize,
        kind: BackendKind,
    ) -> Result<RunReport>
    where
        Banded<T>: AsBandStorageMut,
    {
        match kind {
            BackendKind::Sequential => self.reduce_with(&SequentialBackend::new(), a, bw),
            BackendKind::Threadpool => self.reduce_with(&self.threadpool, a, bw),
            // Borrows the resident pool: no extra threads, just the
            // environment-resolved kernel spec on the packed path.
            BackendKind::Simd => self.reduce_with(&SimdBackend::borrowing(self.pool()), a, bw),
            other => Err(Error::Config(format!(
                "reduce_native cannot run backend {other:?}; use reduce_pjrt"
            ))),
        }
    }

    /// Run the reduction through pre-compiled PJRT artifacts.
    ///
    /// [`BackendKind::Pjrt`] walks the launch plan through the engine's
    /// per-launch executables (device-resident chaining, empty cycles
    /// never launched); [`BackendKind::PjrtFused`] issues one call per
    /// bandwidth stage. Both derive their launch metrics from the same
    /// plan value, so the two kinds report identical schedules.
    pub fn reduce_pjrt<T: Scalar>(
        &self,
        engine: &PjrtEngine,
        a: &mut Banded<T>,
        kind: BackendKind,
    ) -> Result<RunReport>
    where
        Banded<T>: AsBandStorageMut,
    {
        let n = a.n();
        let manifest = engine.manifest();
        let bw = manifest.bw;
        // The plan the artifacts implement: the manifest's own (bw, tw)
        // variant — cross-checked against the Rust schedule at load.
        let variant_params = TuneParams {
            tpb: self.params.tpb,
            tw: manifest.tw,
            max_blocks: self.params.max_blocks,
        };
        let plan = LaunchPlan::for_problem(n, bw, &variant_params);
        let capacity = plan.capacity;
        // Artifacts execute in f32 regardless of the in-memory precision.
        let es = 4;
        let t_start = Instant::now();
        let mut m = LaunchMetrics::default();
        match kind {
            BackendKind::Pjrt => {
                let exec = execute_plan_on_engine(engine, &plan, &mut [a.as_band_storage_mut()])?;
                m = exec.per_problem.into_iter().next().unwrap_or_default();
            }
            BackendKind::PjrtFused => {
                engine.reduce_banded(a, true)?;
                // The fused artifact runs the same launches inside one
                // call per stage; account them from the plan.
                for li in 0..plan.num_launches() {
                    m.record_launch(plan.launch_tasks(li), capacity, plan.launch_bytes(li, es));
                }
            }
            other => {
                return Err(Error::Config(format!("reduce_pjrt cannot run backend {other:?}")))
            }
        }
        m.wall = t_start.elapsed();
        Ok(Self::report(kind, n, bw, self.params, m, a))
    }

    fn report<T: Scalar>(
        kind: BackendKind,
        n: usize,
        bw: usize,
        params: TuneParams,
        metrics: LaunchMetrics,
        a: &Banded<T>,
    ) -> RunReport {
        let (diag, superdiag) = a.bidiagonal();
        RunReport {
            backend: kind,
            n,
            bw,
            params,
            metrics,
            diag: diag.iter().map(|v| v.to_f64()).collect(),
            superdiag: superdiag.iter().map(|v| v.to_f64()).collect(),
            residual_off_band: a.max_off_band(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn native_backends_agree_and_report_metrics() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 8 };
        let coord = Coordinator::new(params, 4);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (n, bw) = (64, 8);
        let mut a1 = random_banded::<f64>(n, bw, 4, &mut rng);
        let mut a2 = a1.clone();
        let mut a3 = a1.clone();
        let r1 = coord.reduce_native(&mut a1, bw, BackendKind::Sequential).unwrap();
        let r2 = coord.reduce_native(&mut a2, bw, BackendKind::Threadpool).unwrap();
        let r3 = coord.reduce_native(&mut a3, bw, BackendKind::Simd).unwrap();
        assert_eq!(a1, a2);
        // The SIMD kind borrows the resident pool; under the default
        // (non-contracting) spec it matches the oracle bitwise too.
        if std::env::var("BSVD_SIMD_CONTRACT").as_deref() != Ok("1") {
            assert_eq!(a1, a3);
        }
        assert_eq!(r3.backend, BackendKind::Simd);
        assert_eq!(r1.metrics.per_launch, r3.metrics.per_launch);
        assert_eq!(r1.metrics.launches, r2.metrics.launches);
        assert_eq!(r1.metrics.tasks, r2.metrics.tasks);
        assert_eq!(r1.metrics.per_launch, r2.metrics.per_launch);
        assert_eq!(r1.metrics.bytes, r2.metrics.bytes);
        assert_eq!(r1.residual_off_band, 0.0);
        assert!(r1.metrics.max_parallel >= 1);
        assert!(r1.metrics.avg_parallel() > 0.0);
        assert_eq!(r1.backend, BackendKind::Sequential);
        assert_eq!(r2.backend, BackendKind::Threadpool);
    }

    #[test]
    fn metrics_match_the_launch_plan_exactly() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 8 };
        let coord = Coordinator::new(params, 2);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (n, bw) = (72, 9);
        let plan = coord.launch_plan(n, bw);
        let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let r = coord.reduce_native(&mut a, bw, BackendKind::Threadpool).unwrap();
        assert_eq!(r.metrics.launches, plan.num_launches());
        assert_eq!(r.metrics.tasks, plan.total_tasks());
        for (li, &got) in r.metrics.per_launch.iter().enumerate() {
            assert_eq!(got as usize, plan.launch_tasks(li), "launch {li}");
        }
    }

    #[test]
    fn explicit_backend_object_matches_kind_selection() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 6 };
        let coord = Coordinator::new(params, 2);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let (n, bw) = (48, 6);
        let mut a1 = random_banded::<f64>(n, bw, 3, &mut rng);
        let mut a2 = a1.clone();
        let via_kind = coord.reduce_native(&mut a1, bw, BackendKind::Sequential).unwrap();
        let via_trait = coord.reduce_with(&SequentialBackend::new(), &mut a2, bw).unwrap();
        assert_eq!(a1, a2);
        assert_eq!(via_kind.diag, via_trait.diag);
        assert_eq!(via_kind.metrics.per_launch, via_trait.metrics.per_launch);
    }

    #[test]
    fn unrolling_is_detected_when_capacity_small() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 1 };
        let coord = Coordinator::new(params, 2);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (n, bw) = (96, 8);
        let mut a = random_banded::<f64>(n, bw, 4, &mut rng);
        let r = coord.reduce_native(&mut a, bw, BackendKind::Threadpool).unwrap();
        assert!(r.metrics.unrolled_launches > 0);
    }

    #[test]
    fn storage_too_small_is_rejected() {
        let params = TuneParams { tpb: 32, tw: 8, max_blocks: 8 };
        let coord = Coordinator::new(params, 1);
        let mut a = Banded::<f64>::zeros(32, 9, 1); // kd_sub 1 < tw 8
        assert!(coord.reduce_native(&mut a, 8, BackendKind::Sequential).is_err());
    }

    #[test]
    fn pjrt_backend_through_native_entry_is_rejected() {
        let params = TuneParams::default();
        let coord = Coordinator::new(params, 1);
        let mut a = Banded::<f64>::for_reduction(16, 2, 1);
        assert!(coord.reduce_native(&mut a, 2, BackendKind::Pjrt).is_err());
    }
}
