//! Launch-loop metrics collected by the coordinator.

use std::time::Duration;

/// Parallelism/occupancy accounting across a reduction's launch loop.
#[derive(Clone, Debug, Default)]
pub struct LaunchMetrics {
    pub launches: usize,
    pub tasks: usize,
    pub max_parallel: usize,
    /// Launches whose task count exceeded the block capacity (software
    /// loop unrolling engaged, §III-C-c).
    pub unrolled_launches: usize,
    /// Algorithmic byte traffic ([`crate::plan::slot_bytes`]) across all
    /// launches — derived from the same [`crate::plan::LaunchPlan`] the
    /// simulator costs, so predicted and executed traffic agree exactly.
    pub bytes: u64,
    /// Tasks per launch, in execution order (launch-by-launch record the
    /// plan-consistency property test compares against the simulator).
    pub per_launch: Vec<u32>,
    pub wall: Duration,
}

impl LaunchMetrics {
    pub fn record_launch(&mut self, tasks: usize, capacity: usize, bytes: u64) {
        self.launches += 1;
        self.tasks += tasks;
        self.max_parallel = self.max_parallel.max(tasks);
        self.bytes += bytes;
        self.per_launch.push(tasks as u32);
        if tasks > capacity {
            self.unrolled_launches += 1;
        }
    }

    pub fn avg_parallel(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.tasks as f64 / self.launches as f64
        }
    }

    /// Mean launch occupancy against a block capacity: tasks filled per
    /// capacity slot offered. Can exceed 1.0 when software loop unrolling
    /// engages (more tasks than blocks, §III-C-c).
    pub fn occupancy_ratio(&self, capacity: usize) -> f64 {
        if self.launches == 0 || capacity == 0 {
            0.0
        } else {
            self.tasks as f64 / (self.launches * capacity) as f64
        }
    }

    pub fn merge(&mut self, o: &LaunchMetrics) {
        self.launches += o.launches;
        self.tasks += o.tasks;
        self.max_parallel = self.max_parallel.max(o.max_parallel);
        self.unrolled_launches += o.unrolled_launches;
        self.bytes += o.bytes;
        self.per_launch.extend_from_slice(&o.per_launch);
        self.wall += o.wall;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_averages() {
        let mut m = LaunchMetrics::default();
        m.record_launch(4, 8, 100);
        m.record_launch(10, 8, 250);
        assert_eq!(m.launches, 2);
        assert_eq!(m.tasks, 14);
        assert_eq!(m.max_parallel, 10);
        assert_eq!(m.unrolled_launches, 1);
        assert_eq!(m.bytes, 350);
        assert_eq!(m.per_launch, vec![4, 10]);
        assert!((m.avg_parallel() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn occupancy_ratio_counts_filled_slots() {
        let mut m = LaunchMetrics::default();
        assert_eq!(m.occupancy_ratio(8), 0.0);
        m.record_launch(4, 8, 0);
        m.record_launch(8, 8, 0);
        assert!((m.occupancy_ratio(8) - 0.75).abs() < 1e-12);
        // Unrolled launches push the ratio past 1.
        m.record_launch(20, 8, 0);
        assert!(m.occupancy_ratio(8) > 1.0);
        assert_eq!(m.occupancy_ratio(0), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LaunchMetrics::default();
        a.record_launch(3, 8, 10);
        let mut b = LaunchMetrics::default();
        b.record_launch(5, 8, 20);
        a.merge(&b);
        assert_eq!(a.launches, 2);
        assert_eq!(a.tasks, 8);
        assert_eq!(a.max_parallel, 5);
        assert_eq!(a.bytes, 30);
        assert_eq!(a.per_launch, vec![3, 5]);
    }
}
