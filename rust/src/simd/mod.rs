//! Explicit-SIMD execution path for the packed-tile cycle kernels.
//!
//! The packed-tile workspace (`bulge::cycle::exec_cycle_packed`) exists
//! to make every reflector generate/apply touch **contiguous** memory —
//! this module cashes that contiguity in. It provides fixed-width lane
//! kernels ([`lane`]: `F64x4` / `F32x8`) for the two hot shapes of the
//! cycle kernel — streaming FMA reflector-apply over packed rows/columns
//! and the horizontal-reduction column norm behind
//! [`crate::householder::make_reflector`] — dispatched per call through a
//! resolved [`SimdSpec`].
//!
//! # Dispatch
//!
//! - [`SimdIsa::Scalar`] — the exact scalar loops the generic cycle
//!   kernels always ran; the fallback and the reference.
//! - [`SimdIsa::Portable`] / [`SimdIsa::Neon`] — the lane kernels
//!   compiled with the build's baseline features (NEON is baseline on
//!   aarch64, so no runtime gate is needed there).
//! - [`SimdIsa::Avx2Fma`] — the same lane bodies recompiled under
//!   `#[target_feature(enable = "avx2,fma")]`, selected only after
//!   runtime detection ([`detect_isa`]).
//!
//! # Equivalence contract
//!
//! Element-wise lane ops (fma/mul/sub) round each lane exactly like the
//! scalar loop rounds each element, so every ISA produces
//! **bitwise-identical** storage — the backend-equivalence property in
//! `rust/tests/plan_consistency.rs` holds `BackendKind::Simd` to the
//! sequential oracle bitwise. Reductions (the dot product in the left
//! update, the sum of squares in the column norm) are order-sensitive;
//! by default they stay sequential (bitwise). Opting in to
//! `BSVD_SIMD_CONTRACT=1` reassociates them into **fixed-width** lane
//! partials (ISA-independent widths, fixed tree-order fold), trading
//! bitwise identity for a documented ulp bound — see
//! `docs/backends.md`.
//!
//! # Environment knobs (read once per process)
//!
//! - `BSVD_SIMD=auto|force|off` — `auto` (default) uses the detected
//!   ISA, falling back to scalar; `force` uses the detected ISA but
//!   falls back to [`SimdIsa::Portable`] (so the lane code paths are
//!   exercised on any host); `off` pins [`SimdIsa::Scalar`].
//! - `BSVD_SIMD_CONTRACT=1` — allow contracted (reassociated)
//!   reductions; ignored when the ISA resolves to scalar.

pub mod aligned;
pub mod kernels;
pub mod lane;

pub use aligned::AlignedVec;

use std::sync::OnceLock;

/// The instruction-set flavor a [`SimdSpec`] dispatches vector kernels
/// to. Construction goes through [`detect_isa`] / [`SimdSpec::resolve`];
/// in particular [`SimdIsa::Avx2Fma`] is only ever produced after a
/// positive runtime feature check, which is what makes the
/// `target_feature` calls in [`kernels`] sound.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SimdIsa {
    /// Plain scalar loops — the fallback and the bitwise reference.
    Scalar,
    /// Fixed-width lane kernels compiled with the build's baseline
    /// target features (auto-vectorizable, no runtime gate).
    Portable,
    /// AArch64 NEON — baseline on every aarch64 target, so it is the
    /// portable lane path compiled with NEON available.
    Neon,
    /// x86-64 AVX2 + FMA, entered through runtime-detected
    /// function multiversioning.
    Avx2Fma,
}

impl SimdIsa {
    pub fn name(self) -> &'static str {
        match self {
            SimdIsa::Scalar => "scalar",
            SimdIsa::Portable => "portable",
            SimdIsa::Neon => "neon",
            SimdIsa::Avx2Fma => "avx2+fma",
        }
    }
}

/// Resolved SIMD configuration, passed by value into every vector kernel
/// call. [`SimdSpec::scalar`] is the identity spec every pre-existing
/// entry point uses; [`SimdSpec::from_env`] is what
/// [`crate::backend::SimdBackend`] resolves once per process.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct SimdSpec {
    /// Which kernel arm element-wise ops dispatch to.
    pub isa: SimdIsa,
    /// Allow contracted (fixed-width reassociated) reductions. `false`
    /// keeps every reduction sequential and therefore bitwise-identical
    /// to the scalar path; `true` is ulp-bounded instead (see module
    /// docs). Never set while `isa` is [`SimdIsa::Scalar`] —
    /// constructors normalize it away.
    pub contract: bool,
}

impl SimdSpec {
    /// The scalar identity spec: every kernel runs the reference loop.
    pub fn scalar() -> Self {
        Self { isa: SimdIsa::Scalar, contract: false }
    }

    /// Spec for an explicit ISA, normalizing `contract` off when the ISA
    /// is scalar (the scalar path has nothing to contract).
    pub fn with_contract(isa: SimdIsa, contract: bool) -> Self {
        Self { isa, contract: contract && isa != SimdIsa::Scalar }
    }

    /// The process-wide spec from `BSVD_SIMD` / `BSVD_SIMD_CONTRACT`,
    /// read once (first call wins, like the other `BSVD_*` knobs).
    /// Tests that need a specific spec should construct it directly
    /// (e.g. [`crate::backend::SimdBackend::with_spec`]) instead of
    /// mutating the environment.
    pub fn from_env() -> Self {
        static SPEC: OnceLock<SimdSpec> = OnceLock::new();
        *SPEC.get_or_init(|| {
            let mode = std::env::var("BSVD_SIMD").unwrap_or_default();
            let contract =
                std::env::var("BSVD_SIMD_CONTRACT").map(|v| v == "1").unwrap_or(false);
            Self::resolve(&mode, contract, detect_isa())
        })
    }

    /// Pure resolution of the `BSVD_SIMD` mode string against a detection
    /// result — the entire policy of [`SimdSpec::from_env`], exposed so
    /// tests can cover it without touching the process environment.
    pub fn resolve(mode: &str, contract: bool, detected: Option<SimdIsa>) -> Self {
        let isa = match mode {
            "off" | "0" | "scalar" => SimdIsa::Scalar,
            "force" | "on" | "1" => detected.unwrap_or(SimdIsa::Portable),
            // "auto", the empty default, and anything unrecognized.
            _ => detected.unwrap_or(SimdIsa::Scalar),
        };
        Self::with_contract(isa, contract)
    }

    /// Whether any lane kernel arm is active (false = pure scalar).
    pub fn is_vector(self) -> bool {
        self.isa != SimdIsa::Scalar
    }

    /// Human-readable form for provenance/CLI output, e.g.
    /// `"avx2+fma"` or `"portable, contracted reductions"`.
    pub fn describe(self) -> String {
        if self.contract {
            format!("{}, contracted reductions", self.isa.name())
        } else {
            self.isa.name().to_string()
        }
    }
}

/// Runtime ISA detection: AVX2+FMA on x86-64 when the CPU reports both,
/// NEON on aarch64 (baseline), `None` elsewhere.
pub fn detect_isa() -> Option<SimdIsa> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            Some(SimdIsa::Avx2Fma)
        } else {
            None
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Some(SimdIsa::Neon)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_spec_is_the_identity() {
        let spec = SimdSpec::scalar();
        assert_eq!(spec.isa, SimdIsa::Scalar);
        assert!(!spec.contract);
        assert!(!spec.is_vector());
        assert_eq!(spec.describe(), "scalar");
    }

    #[test]
    fn resolve_covers_the_knob_table() {
        let detected = Some(SimdIsa::Avx2Fma);
        // off pins scalar regardless of detection.
        assert_eq!(SimdSpec::resolve("off", false, detected).isa, SimdIsa::Scalar);
        assert_eq!(SimdSpec::resolve("0", true, detected).isa, SimdIsa::Scalar);
        // auto (and the empty default) takes the detected ISA, scalar
        // when there is none.
        assert_eq!(SimdSpec::resolve("auto", false, detected).isa, SimdIsa::Avx2Fma);
        assert_eq!(SimdSpec::resolve("", false, detected).isa, SimdIsa::Avx2Fma);
        assert_eq!(SimdSpec::resolve("auto", false, None).isa, SimdIsa::Scalar);
        // force falls back to the portable lane path, never to scalar.
        assert_eq!(SimdSpec::resolve("force", false, None).isa, SimdIsa::Portable);
        assert_eq!(SimdSpec::resolve("force", false, detected).isa, SimdIsa::Avx2Fma);
        assert_eq!(SimdSpec::resolve("1", false, None).isa, SimdIsa::Portable);
    }

    #[test]
    fn contract_is_normalized_off_on_the_scalar_isa() {
        assert!(!SimdSpec::resolve("off", true, Some(SimdIsa::Avx2Fma)).contract);
        assert!(SimdSpec::resolve("force", true, None).contract);
        assert!(!SimdSpec::with_contract(SimdIsa::Scalar, true).contract);
        assert!(SimdSpec::with_contract(SimdIsa::Portable, true).contract);
        assert_eq!(
            SimdSpec::with_contract(SimdIsa::Portable, true).describe(),
            "portable, contracted reductions"
        );
    }

    #[test]
    fn from_env_is_stable_across_calls() {
        // Read-once semantics: whatever the first call resolved, every
        // later call returns the identical spec.
        assert_eq!(SimdSpec::from_env(), SimdSpec::from_env());
    }

    #[test]
    fn detection_never_reports_a_foreign_isa() {
        match detect_isa() {
            Some(SimdIsa::Avx2Fma) => assert!(cfg!(target_arch = "x86_64")),
            Some(SimdIsa::Neon) => assert!(cfg!(target_arch = "aarch64")),
            Some(other) => panic!("detect_isa returned non-hardware ISA {other:?}"),
            None => {}
        }
    }
}
