//! Fixed-width lane types: the portable vector registers of the SIMD
//! kernels.
//!
//! Each type is a plain array the compiler can keep in one vector
//! register; every op is `#[inline(always)]` so the
//! `#[target_feature(enable = "avx2,fma")]` wrappers in
//! [`crate::simd::kernels`] recompile the same bodies with wider
//! instructions. Widths are **fixed per element type** (4×f64 = 8×f32 =
//! one 256-bit register), not per host ISA — that is what makes the
//! contracted-reduction mode deterministic across machines.
//!
//! Element-wise ops round per lane exactly like the scalar loop rounds
//! per element (IEEE add/sub/mul/fma are correctly rounded), so results
//! are bitwise-identical to scalar regardless of which arm ran.
//! [`F64x4::hsum`] folds in a fixed tree order, so contracted reductions
//! are deterministic too — just not scalar-ordered.

macro_rules! define_lane {
    ($(#[$doc:meta])* $name:ident, $elem:ty, $lanes:expr) => {
        $(#[$doc])*
        #[derive(Copy, Clone, Debug)]
        pub struct $name(pub [$elem; $lanes]);

        impl $name {
            /// Lane count — fixed for this element type on every ISA.
            pub const LANES: usize = $lanes;

            /// All lanes set to `v`.
            #[inline(always)]
            pub fn splat(v: $elem) -> Self {
                Self([v; $lanes])
            }

            /// Load the first `LANES` elements of `src`.
            #[inline(always)]
            pub fn load(src: &[$elem]) -> Self {
                Self(std::array::from_fn(|i| src[i]))
            }

            /// Store into the first `LANES` elements of `dst`.
            #[inline(always)]
            pub fn store(self, dst: &mut [$elem]) {
                dst[..$lanes].copy_from_slice(&self.0);
            }

            /// Per-lane `self + rhs`.
            #[inline(always)]
            pub fn add(self, rhs: Self) -> Self {
                Self(std::array::from_fn(|i| self.0[i] + rhs.0[i]))
            }

            /// Per-lane `self - rhs`.
            #[inline(always)]
            pub fn sub(self, rhs: Self) -> Self {
                Self(std::array::from_fn(|i| self.0[i] - rhs.0[i]))
            }

            /// Per-lane `self * rhs`.
            #[inline(always)]
            pub fn mul(self, rhs: Self) -> Self {
                Self(std::array::from_fn(|i| self.0[i] * rhs.0[i]))
            }

            /// Per-lane fused `self * a + b` (one rounding, like the
            /// scalar kernels' `mul_add`).
            #[inline(always)]
            pub fn fma(self, a: Self, b: Self) -> Self {
                Self(std::array::from_fn(|i| self.0[i].mul_add(a.0[i], b.0[i])))
            }

            /// Horizontal sum in a fixed halving-tree order —
            /// `(l0+l2) + (l1+l3)` for 4 lanes — independent of ISA, so
            /// contracted reductions reproduce across hosts.
            #[inline(always)]
            pub fn hsum(self) -> $elem {
                let mut v = self.0;
                let mut half = $lanes / 2;
                while half > 0 {
                    for i in 0..half {
                        v[i] += v[i + half];
                    }
                    half /= 2;
                }
                v[0]
            }
        }
    };
}

define_lane!(
    /// Four f64 lanes — one 256-bit register.
    F64x4,
    f64,
    4
);
define_lane!(
    /// Eight f32 lanes — one 256-bit register.
    F32x8,
    f32,
    8
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_ops_match_scalar_bitwise() {
        let a = [1.5f64, -2.25, 1e-300, 3.7e10];
        let b = [0.1f64, 7.5, -1e300, 0.333];
        let c = [9.0f64, -0.5, 2.0, 1e-5];
        let va = F64x4::load(&a);
        let vb = F64x4::load(&b);
        let vc = F64x4::load(&c);
        for i in 0..4 {
            assert_eq!(va.add(vb).0[i].to_bits(), (a[i] + b[i]).to_bits());
            assert_eq!(va.sub(vb).0[i].to_bits(), (a[i] - b[i]).to_bits());
            assert_eq!(va.mul(vb).0[i].to_bits(), (a[i] * b[i]).to_bits());
            assert_eq!(va.fma(vb, vc).0[i].to_bits(), a[i].mul_add(b[i], c[i]).to_bits());
        }
    }

    #[test]
    fn load_store_splat_round_trip() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 99.0];
        let v = F32x8::load(&src);
        let mut dst = [0.0f32; 10];
        v.store(&mut dst);
        assert_eq!(&dst[..8], &src[..8]);
        assert_eq!(dst[8], 0.0, "store writes exactly LANES elements");
        assert_eq!(F64x4::splat(2.5).0, [2.5; 4]);
        assert_eq!(F32x8::LANES, 8);
        assert_eq!(F64x4::LANES, 4);
    }

    #[test]
    fn hsum_folds_in_the_documented_tree_order() {
        let v = F64x4([1e16, 1.0, -1e16, 1.0]);
        // Halving tree: lanes fold as (l0+l2) + (l1+l3), so the two
        // big values cancel exactly before the small ones are added.
        let want = (1e16f64 + -1e16) + (1.0f64 + 1.0);
        assert_eq!(v.hsum().to_bits(), want.to_bits());
        assert_eq!(v.hsum(), 2.0);
        let w = F32x8([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let want32 = ((1.0f32 + 5.0) + (3.0 + 7.0)) + ((2.0 + 6.0) + (4.0 + 8.0));
        assert_eq!(w.hsum().to_bits(), want32.to_bits());
    }
}
