//! Concrete vector kernels behind the [`crate::scalar::Scalar`]
//! `simd_*` hooks — one monomorphic module per vectorizable element
//! type ([`kern_f64`], [`kern_f32`]).
//!
//! Every kernel takes the resolved [`SimdSpec`] and dispatches:
//!
//! - [`SimdIsa::Scalar`] → the exact scalar loop the generic cycle
//!   kernels ran before this module existed (the reference body).
//! - [`SimdIsa::Portable`] / [`SimdIsa::Neon`] → the lane body from
//!   [`crate::simd::lane`], compiled with baseline target features.
//! - [`SimdIsa::Avx2Fma`] → the same lane body recompiled inside a
//!   `#[target_feature(enable = "avx2,fma")]` wrapper; sound because
//!   that ISA is only ever constructed after runtime detection.
//!
//! Element-wise kernels (`fma_axpy`, `scale`, `sub`, `sub_scaled`) are
//! bitwise-identical across all three arms: each lane op is correctly
//! rounded, exactly like the scalar loop's per-element op. The
//! reductions (`dot_fma`, `tail_sum_squares`) run the sequential
//! reference order unless `spec.contract` is set, in which case they
//! use fixed-width lane partials folded in [`lane`]'s deterministic
//! tree order — reproducible everywhere, but reassociated, so only
//! ulp-close to the sequential result (bound tested below).

use super::lane::{F32x8, F64x4};
use super::{SimdIsa, SimdSpec};

macro_rules! lane_kernels {
    ($mod_name:ident, $ty:ty, $lane:ident) => {
        pub mod $mod_name {
            use super::{$lane, SimdIsa, SimdSpec};

            const N: usize = $lane::LANES;

            /// `w[i] = v.mul_add(s[i], w[i])` over the zipped prefix —
            /// the streaming reflector-apply accumulation.
            pub fn fma_axpy(spec: SimdSpec, w: &mut [$ty], v: $ty, s: &[$ty]) {
                match spec.isa {
                    SimdIsa::Scalar => scalar_fma_axpy(w, v, s),
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: Avx2Fma is only constructed after runtime
                    // detection of avx2+fma (see `SimdIsa` docs).
                    SimdIsa::Avx2Fma => unsafe { avx2::fma_axpy(w, v, s) },
                    _ => portable_fma_axpy(w, v, s),
                }
            }

            /// `w[i] = c * w[i]` — the `tau` scaling pass.
            pub fn scale(spec: SimdSpec, w: &mut [$ty], c: $ty) {
                match spec.isa {
                    SimdIsa::Scalar => scalar_scale(w, c),
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: as in `fma_axpy`.
                    SimdIsa::Avx2Fma => unsafe { avx2::scale(w, c) },
                    _ => portable_scale(w, c),
                }
            }

            /// `dst[i] = dst[i] - src[i]` over the zipped prefix.
            pub fn sub(spec: SimdSpec, dst: &mut [$ty], src: &[$ty]) {
                match spec.isa {
                    SimdIsa::Scalar => scalar_sub(dst, src),
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: as in `fma_axpy`.
                    SimdIsa::Avx2Fma => unsafe { avx2::sub(dst, src) },
                    _ => portable_sub(dst, src),
                }
            }

            /// `dst[i] = dst[i] - src[i] * c` over the zipped prefix —
            /// the rank-1 update column pass.
            pub fn sub_scaled(spec: SimdSpec, dst: &mut [$ty], src: &[$ty], c: $ty) {
                match spec.isa {
                    SimdIsa::Scalar => scalar_sub_scaled(dst, src, c),
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: as in `fma_axpy`.
                    SimdIsa::Avx2Fma => unsafe { avx2::sub_scaled(dst, src, c) },
                    _ => portable_sub_scaled(dst, src, c),
                }
            }

            /// Fused dot product `init + Σ v[i]*s[i]`, accumulated with
            /// `mul_add`. Sequential (bitwise vs the scalar reference)
            /// unless `spec.contract` — then fixed-width lane partials.
            pub fn dot_fma(spec: SimdSpec, init: $ty, v: &[$ty], s: &[$ty]) -> $ty {
                if spec.contract && spec.isa != SimdIsa::Scalar {
                    #[cfg(target_arch = "x86_64")]
                    if spec.isa == SimdIsa::Avx2Fma {
                        // SAFETY: as in `fma_axpy`.
                        return unsafe { avx2::dot_fma_contracted(init, v, s) };
                    }
                    return portable_dot_fma_contracted(init, v, s);
                }
                sequential_dot_fma(init, v, s)
            }

            /// Widened sum of squares `Σ (x[i] as f64)^2` — the column
            /// norm behind `make_reflector`. Sequential unless
            /// `spec.contract` — then four fixed f64 partials (fixed
            /// regardless of the element type, so f32 and f64 problems
            /// contract identically).
            pub fn tail_sum_squares(spec: SimdSpec, x: &[$ty]) -> f64 {
                if spec.contract && spec.isa != SimdIsa::Scalar {
                    #[cfg(target_arch = "x86_64")]
                    if spec.isa == SimdIsa::Avx2Fma {
                        // SAFETY: as in `fma_axpy`.
                        return unsafe { avx2::tail_sum_squares_contracted(x) };
                    }
                    return portable_tail_sum_squares_contracted(x);
                }
                sequential_tail_sum_squares(x)
            }

            // --- scalar reference bodies ---

            fn scalar_fma_axpy(w: &mut [$ty], v: $ty, s: &[$ty]) {
                for (wi, si) in w.iter_mut().zip(s.iter()) {
                    *wi = v.mul_add(*si, *wi);
                }
            }

            fn scalar_scale(w: &mut [$ty], c: $ty) {
                for wi in w.iter_mut() {
                    *wi *= c;
                }
            }

            fn scalar_sub(dst: &mut [$ty], src: &[$ty]) {
                for (di, si) in dst.iter_mut().zip(src.iter()) {
                    *di -= *si;
                }
            }

            fn scalar_sub_scaled(dst: &mut [$ty], src: &[$ty], c: $ty) {
                for (di, si) in dst.iter_mut().zip(src.iter()) {
                    *di -= *si * c;
                }
            }

            fn sequential_dot_fma(init: $ty, v: &[$ty], s: &[$ty]) -> $ty {
                let mut acc = init;
                for (vi, si) in v.iter().zip(s.iter()) {
                    acc = vi.mul_add(*si, acc);
                }
                acc
            }

            fn sequential_tail_sum_squares(x: &[$ty]) -> f64 {
                let mut ssq = 0.0f64;
                for v in x {
                    let t = f64::from(*v);
                    ssq += t * t;
                }
                ssq
            }

            // --- portable lane bodies (also the avx2 bodies, below) ---

            #[inline(always)]
            fn portable_fma_axpy(w: &mut [$ty], v: $ty, s: &[$ty]) {
                let n = w.len().min(s.len());
                let vv = $lane::splat(v);
                let mut i = 0;
                while i + N <= n {
                    vv.fma($lane::load(&s[i..]), $lane::load(&w[i..])).store(&mut w[i..]);
                    i += N;
                }
                while i < n {
                    w[i] = v.mul_add(s[i], w[i]);
                    i += 1;
                }
            }

            #[inline(always)]
            fn portable_scale(w: &mut [$ty], c: $ty) {
                let n = w.len();
                let cc = $lane::splat(c);
                let mut i = 0;
                while i + N <= n {
                    cc.mul($lane::load(&w[i..])).store(&mut w[i..]);
                    i += N;
                }
                while i < n {
                    w[i] *= c;
                    i += 1;
                }
            }

            #[inline(always)]
            fn portable_sub(dst: &mut [$ty], src: &[$ty]) {
                let n = dst.len().min(src.len());
                let mut i = 0;
                while i + N <= n {
                    $lane::load(&dst[i..]).sub($lane::load(&src[i..])).store(&mut dst[i..]);
                    i += N;
                }
                while i < n {
                    dst[i] -= src[i];
                    i += 1;
                }
            }

            #[inline(always)]
            fn portable_sub_scaled(dst: &mut [$ty], src: &[$ty], c: $ty) {
                let n = dst.len().min(src.len());
                let cc = $lane::splat(c);
                let mut i = 0;
                while i + N <= n {
                    $lane::load(&dst[i..])
                        .sub($lane::load(&src[i..]).mul(cc))
                        .store(&mut dst[i..]);
                    i += N;
                }
                while i < n {
                    dst[i] -= src[i] * c;
                    i += 1;
                }
            }

            #[inline(always)]
            fn portable_dot_fma_contracted(init: $ty, v: &[$ty], s: &[$ty]) -> $ty {
                let n = v.len().min(s.len());
                let mut acc = $lane::splat(0.0);
                let mut i = 0;
                while i + N <= n {
                    acc = $lane::load(&v[i..]).fma($lane::load(&s[i..]), acc);
                    i += N;
                }
                let mut total = init + acc.hsum();
                while i < n {
                    total = v[i].mul_add(s[i], total);
                    i += 1;
                }
                total
            }

            #[inline(always)]
            fn portable_tail_sum_squares_contracted(x: &[$ty]) -> f64 {
                // Four f64 partials for every element type: the
                // accumulation is widened to f64 first, so the partial
                // width cannot follow the element lane count.
                const P: usize = 4;
                let mut acc = [0.0f64; P];
                let chunks = x.len() / P;
                for c in 0..chunks {
                    for l in 0..P {
                        let t = f64::from(x[c * P + l]);
                        acc[l] += t * t;
                    }
                }
                let mut ssq = (acc[0] + acc[2]) + (acc[1] + acc[3]);
                for v in &x[chunks * P..] {
                    let t = f64::from(*v);
                    ssq += t * t;
                }
                ssq
            }

            /// The portable lane bodies recompiled with AVX2+FMA enabled
            /// (function multiversioning): `#[inline(always)]` bodies
            /// inline here and pick up the wider codegen.
            #[cfg(target_arch = "x86_64")]
            mod avx2 {
                /// # Safety
                /// Requires avx2+fma, verified at runtime by the caller.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn fma_axpy(w: &mut [$ty], v: $ty, s: &[$ty]) {
                    super::portable_fma_axpy(w, v, s)
                }

                /// # Safety
                /// Requires avx2+fma, verified at runtime by the caller.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn scale(w: &mut [$ty], c: $ty) {
                    super::portable_scale(w, c)
                }

                /// # Safety
                /// Requires avx2+fma, verified at runtime by the caller.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn sub(dst: &mut [$ty], src: &[$ty]) {
                    super::portable_sub(dst, src)
                }

                /// # Safety
                /// Requires avx2+fma, verified at runtime by the caller.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn sub_scaled(dst: &mut [$ty], src: &[$ty], c: $ty) {
                    super::portable_sub_scaled(dst, src, c)
                }

                /// # Safety
                /// Requires avx2+fma, verified at runtime by the caller.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn dot_fma_contracted(init: $ty, v: &[$ty], s: &[$ty]) -> $ty {
                    super::portable_dot_fma_contracted(init, v, s)
                }

                /// # Safety
                /// Requires avx2+fma, verified at runtime by the caller.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn tail_sum_squares_contracted(x: &[$ty]) -> f64 {
                    super::portable_tail_sum_squares_contracted(x)
                }
            }
        }
    };
}

lane_kernels!(kern_f64, f64, F64x4);
lane_kernels!(kern_f32, f32, F32x8);

#[cfg(test)]
mod tests {
    use super::super::detect_isa;
    use super::*;

    /// Every ISA arm constructible on this host, scalar first.
    fn arms() -> Vec<SimdSpec> {
        let mut specs = vec![SimdSpec::scalar(), SimdSpec::with_contract(SimdIsa::Portable, false)];
        if let Some(isa) = detect_isa() {
            specs.push(SimdSpec::with_contract(isa, false));
        }
        specs
    }

    fn data_f64(len: usize) -> (Vec<f64>, Vec<f64>) {
        // Awkward magnitudes on purpose: rounding differences would show.
        let a: Vec<f64> = (0..len).map(|i| ((i * 37 + 11) % 97) as f64 * 0.671 - 31.0).collect();
        let b: Vec<f64> = (0..len).map(|i| ((i * 53 + 7) % 89) as f64 * 1.37e-3 + 0.11).collect();
        (a, b)
    }

    #[test]
    fn elementwise_kernels_are_bitwise_identical_across_arms() {
        // Lengths straddle the lane width: below, exact multiples, and
        // off-by-one tails.
        for len in [0usize, 1, 3, 4, 7, 8, 9, 31, 64, 65] {
            let (a, b) = data_f64(len);
            for spec in arms() {
                let mut w = a.clone();
                kern_f64::fma_axpy(spec, &mut w, 1.75, &b);
                let mut w_ref = a.clone();
                kern_f64::fma_axpy(SimdSpec::scalar(), &mut w_ref, 1.75, &b);
                assert_eq!(bits(&w), bits(&w_ref), "fma_axpy {spec:?} len {len}");

                let mut w = a.clone();
                kern_f64::scale(spec, &mut w, -0.37);
                let mut w_ref = a.clone();
                kern_f64::scale(SimdSpec::scalar(), &mut w_ref, -0.37);
                assert_eq!(bits(&w), bits(&w_ref), "scale {spec:?} len {len}");

                let mut w = a.clone();
                kern_f64::sub(spec, &mut w, &b);
                let mut w_ref = a.clone();
                kern_f64::sub(SimdSpec::scalar(), &mut w_ref, &b);
                assert_eq!(bits(&w), bits(&w_ref), "sub {spec:?} len {len}");

                let mut w = a.clone();
                kern_f64::sub_scaled(spec, &mut w, &b, 2.625);
                let mut w_ref = a.clone();
                kern_f64::sub_scaled(SimdSpec::scalar(), &mut w_ref, &b, 2.625);
                assert_eq!(bits(&w), bits(&w_ref), "sub_scaled {spec:?} len {len}");
            }
        }
    }

    #[test]
    fn f32_kernels_are_bitwise_identical_across_arms() {
        for len in [0usize, 5, 8, 13, 16, 40] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32) * 0.37 - 3.0).collect();
            let b: Vec<f32> = (0..len).map(|i| 1.0 / (i as f32 + 1.5)).collect();
            for spec in arms() {
                let mut w = a.clone();
                kern_f32::fma_axpy(spec, &mut w, -1.1, &b);
                let mut w_ref = a.clone();
                kern_f32::fma_axpy(SimdSpec::scalar(), &mut w_ref, -1.1, &b);
                let same = w.iter().zip(&w_ref).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "f32 fma_axpy {spec:?} len {len}");
            }
        }
    }

    #[test]
    fn uncontracted_reductions_are_bitwise_identical_across_arms() {
        for len in [0usize, 1, 4, 7, 16, 33] {
            let (a, b) = data_f64(len);
            let want_dot = kern_f64::dot_fma(SimdSpec::scalar(), 0.125, &a, &b);
            let want_ssq = kern_f64::tail_sum_squares(SimdSpec::scalar(), &a);
            for spec in arms() {
                let dot = kern_f64::dot_fma(spec, 0.125, &a, &b);
                assert_eq!(dot.to_bits(), want_dot.to_bits(), "dot {spec:?} len {len}");
                let ssq = kern_f64::tail_sum_squares(spec, &a);
                assert_eq!(ssq.to_bits(), want_ssq.to_bits(), "ssq {spec:?} len {len}");
            }
        }
    }

    #[test]
    fn contracted_reductions_are_ulp_bounded_and_host_deterministic() {
        for len in [3usize, 8, 15, 64, 257] {
            let (a, b) = data_f64(len);
            let seq_dot = kern_f64::dot_fma(SimdSpec::scalar(), 1.0, &a, &b);
            let seq_ssq = kern_f64::tail_sum_squares(SimdSpec::scalar(), &a);
            // Condition-aware bound: n * eps * sum |v_i * s_i| absolute
            // terms (the usual reassociation error bound).
            let mag: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>() + 1.0;
            let bound = len as f64 * f64::EPSILON * mag;
            let portable = SimdSpec::with_contract(SimdIsa::Portable, true);
            assert!(portable.contract);
            let por_dot = kern_f64::dot_fma(portable, 1.0, &a, &b);
            let por_ssq = kern_f64::tail_sum_squares(portable, &a);
            assert!((por_dot - seq_dot).abs() <= bound, "dot len {len}");
            let ssq_mag: f64 = a.iter().map(|x| x * x).sum::<f64>() + 1.0;
            assert!((por_ssq - seq_ssq).abs() <= len as f64 * f64::EPSILON * ssq_mag);
            // Fixed-width partials: the detected wider ISA must contract
            // to the *same bits* as the portable arm.
            if let Some(isa) = detect_isa() {
                let wide = SimdSpec::with_contract(isa, true);
                assert_eq!(kern_f64::dot_fma(wide, 1.0, &a, &b).to_bits(), por_dot.to_bits());
                assert_eq!(kern_f64::tail_sum_squares(wide, &a).to_bits(), por_ssq.to_bits());
            }
        }
    }

    #[test]
    fn contract_flag_without_vector_isa_stays_sequential() {
        // `with_contract` normalizes it away, but a hand-built spec must
        // still take the sequential path.
        let spec = SimdSpec { isa: SimdIsa::Scalar, contract: true };
        let (a, b) = data_f64(21);
        let want = kern_f64::dot_fma(SimdSpec::scalar(), 0.0, &a, &b);
        assert_eq!(kern_f64::dot_fma(spec, 0.0, &a, &b).to_bits(), want.to_bits());
    }

    fn bits(xs: &[f64]) -> Vec<u64> {
        xs.iter().map(|x| x.to_bits()).collect()
    }
}
