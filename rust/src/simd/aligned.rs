//! 64-byte-aligned growable buffers for the packed-tile workspaces.
//!
//! `Vec<f64>` guarantees only the element's own alignment (8), so a
//! packed tile starting mid-cache-line splits every vector load that
//! crosses the line. [`AlignedVec`] allocates in 64-byte blocks —
//! cache-line and widest-vector-register aligned — so the lane kernels
//! in [`crate::simd::kernels`] never start from a split line. It
//! implements exactly the surface `bulge::cycle::CycleWorkspace` needs
//! (`Deref`/`DerefMut` to `[T]`, `resize`, `Default` for `mem::take`),
//! nothing more.

use std::fmt;
use std::marker::PhantomData;
use std::ops::{Deref, DerefMut};

/// The allocation granule: one cache line.
const BLOCK: usize = 64;

/// One 64-byte-aligned block; `Vec<Chunk>`'s buffer is therefore
/// 64-byte-aligned as a whole (including the dangling pointer of an
/// empty vec, which `Vec` aligns to the element type).
#[derive(Copy, Clone)]
#[repr(C, align(64))]
struct Chunk([u8; BLOCK]);

const ZERO_CHUNK: Chunk = Chunk([0u8; BLOCK]);

/// A growable buffer of `T` whose data pointer is always 64-byte
/// aligned. Grows like the `Vec` it wraps (shrinking keeps capacity);
/// all element access goes through `Deref`/`DerefMut` to `[T]`.
///
/// `T` must be `Copy` and no more than 64-byte aligned — the element
/// types here are the crate's scalar kinds (`f64`/`f32`/`F16`). Every
/// element below `len` is written through [`AlignedVec::resize`] before
/// it is ever exposed, so the `Deref` slice never observes an
/// unwritten value.
pub struct AlignedVec<T> {
    chunks: Vec<Chunk>,
    len: usize,
    _elem: PhantomData<T>,
}

impl<T> Default for AlignedVec<T> {
    fn default() -> Self {
        Self { chunks: Vec::new(), len: 0, _elem: PhantomData }
    }
}

impl<T> Clone for AlignedVec<T> {
    fn clone(&self) -> Self {
        Self { chunks: self.chunks.clone(), len: self.len, _elem: PhantomData }
    }
}

impl<T: Copy> AlignedVec<T> {
    /// An empty buffer (no allocation).
    pub fn new() -> Self {
        Self::default()
    }

    /// A buffer of `len` copies of `fill`.
    pub fn filled(len: usize, fill: T) -> Self {
        let mut v = Self::new();
        v.resize(len, fill);
        v
    }

    /// Resize to `len` elements, writing `fill` into any newly exposed
    /// tail. Shrinking only moves the length; capacity (and the values
    /// beyond `len`) stay, so regrowth re-fills them deterministically.
    pub fn resize(&mut self, len: usize, fill: T) {
        let elem = std::mem::size_of::<T>();
        assert!(elem > 0 && std::mem::align_of::<T>() <= BLOCK);
        let chunks_needed = (len * elem + BLOCK - 1) / BLOCK;
        if chunks_needed > self.chunks.len() {
            self.chunks.resize(chunks_needed, ZERO_CHUNK);
        }
        let old = self.len;
        if len > old {
            let base = self.chunks.as_mut_ptr() as *mut T;
            // SAFETY: the resize above reserved >= len elements' worth of
            // aligned storage; writes go through raw pointers so no
            // reference to a not-yet-written element is ever formed.
            unsafe {
                for i in old..len {
                    base.add(i).write(fill);
                }
            }
        }
        self.len = len;
    }
}

impl<T> Deref for AlignedVec<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        // SAFETY: every element below `len` was written by `resize`, the
        // chunk storage covers `len * size_of::<T>()` bytes, and Chunk's
        // 64-byte alignment satisfies T's.
        unsafe { std::slice::from_raw_parts(self.chunks.as_ptr() as *const T, self.len) }
    }
}

impl<T> DerefMut for AlignedVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // SAFETY: as in `deref`, plus exclusive access through `&mut self`.
        unsafe { std::slice::from_raw_parts_mut(self.chunks.as_mut_ptr() as *mut T, self.len) }
    }
}

impl<T: fmt::Debug> fmt::Debug for AlignedVec<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr_of<T>(v: &AlignedVec<T>) -> usize {
        v.as_ptr() as usize
    }

    #[test]
    fn data_pointer_is_64_byte_aligned_through_growth() {
        let mut v = AlignedVec::<f64>::filled(3, 1.5);
        assert_eq!(addr_of(&v) % 64, 0);
        for len in [9usize, 64, 65, 1000, 7, 4096] {
            v.resize(len, 0.25);
            assert_eq!(addr_of(&v) % 64, 0, "len {len}");
            assert_eq!(v.len(), len);
        }
        let f32s = AlignedVec::<f32>::filled(129, 0.0);
        assert_eq!(addr_of(&f32s) % 64, 0);
    }

    #[test]
    fn resize_fills_the_exposed_tail_and_keeps_the_prefix() {
        let mut v = AlignedVec::<f64>::filled(4, 2.0);
        v[1] = -7.0;
        v.resize(7, 9.0);
        assert_eq!(&v[..], &[2.0, -7.0, 2.0, 2.0, 9.0, 9.0, 9.0]);
        // Shrink then regrow: the regrown tail is re-filled, not stale.
        v.resize(2, 0.0);
        assert_eq!(&v[..], &[2.0, -7.0]);
        v.resize(4, 5.0);
        assert_eq!(&v[..], &[2.0, -7.0, 5.0, 5.0]);
    }

    #[test]
    fn behaves_like_a_slice_and_supports_mem_take() {
        let mut v = AlignedVec::<f64>::filled(5, 1.0);
        v.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(v.iter().sum::<f64>(), 15.0);
        assert!(!v.is_empty());
        let taken = std::mem::take(&mut v);
        assert_eq!(taken.len(), 5);
        assert!(v.is_empty());
        let cloned = taken.clone();
        assert_eq!(&cloned[..], &taken[..]);
        assert_ne!(addr_of(&cloned), addr_of(&taken));
    }

    #[test]
    fn empty_buffer_is_valid() {
        let v = AlignedVec::<f32>::new();
        assert!(v.is_empty());
        assert_eq!(v.len(), 0);
        assert_eq!(format!("{v:?}"), "[]");
    }
}
