//! Launch-plan IR — the single source of truth for *what the device
//! executes*.
//!
//! The paper's two pillars meet here: the 3-cycle bulge-chasing schedule
//! is **lowered** (by [`crate::bulge::schedule::TaskStream`]) into a
//! backend-agnostic sequence of launches — each a run of [`TaskSlot`]s,
//! stored CSR-style — and every consumer operates on that one value:
//!
//! ```text
//!   schedule (bulge/schedule.rs)
//!        │ lower
//!        ▼
//!   LaunchPlan ──── merge ────▶ LaunchPlan (shared launches, batched)
//!        │                          │
//!        ├──▶ execute (coordinator, batch engine)
//!        └──▶ simulate (simulator::model) — costs the identical value,
//!             so predicted launches/occupancy are exact by construction
//! ```
//!
//! A [`TaskSlot`] is deliberately *symbolic*: it names `(problem, stage,
//! global cycle, task count)` instead of materializing the cycle-tasks.
//! The closed-form schedule reconstructs the task list exactly
//! ([`Stage::tasks_at`]), so a plan for an n = 65536 reduction stays a
//! few MB instead of hundreds; the simulator only ever needs the counts.
//!
//! Ordering contract (what makes merge correct): launches execute in plan
//! order with a barrier between them, and any two slots of the same
//! problem appear in that problem's own stream order. A merge therefore
//! never changes per-problem numerics — batched results stay bitwise
//! identical to solo runs (property-tested in
//! `rust/tests/batch_equivalence.rs`).

pub mod reflectors;

pub use reflectors::ReflectorLog;

use crate::bulge::schedule::{stage_plan, Stage, TaskStream};
use crate::config::{PackingPolicy, TuneParams};

/// One problem's contribution to a launch: `count` ready cycle-tasks of
/// stage `stage` at the stage's global cycle `t`. Executors materialize
/// the tasks with `stages[stage].tasks_at(n, t)`; the simulator costs the
/// count directly.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct TaskSlot {
    /// Index into [`LaunchPlan::problems`].
    pub problem: u32,
    /// Index into the problem's stage list.
    pub stage: u32,
    /// Global cycle within the stage (the schedule's `t`).
    pub t: u32,
    /// Ready tasks (> 0; empty cycles are never lowered).
    pub count: u32,
}

/// Static description of one problem in a plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProblemShape {
    pub n: usize,
    pub bw: usize,
    /// Effective inner tilewidth (already clamped to `bw − 1`).
    pub tw: usize,
    /// Successive band-reduction stages, `bw` down to bandwidth 1.
    pub stages: Vec<Stage>,
    /// Non-empty launches this problem contributes.
    pub launches: usize,
    /// Total cycle-tasks across all stages.
    pub tasks: usize,
}

/// The launch-plan IR: an ordered sequence of launches, each a list of
/// [`TaskSlot`]s, stored CSR-style (flat slot array + per-launch end
/// offsets) so single-problem plans cost one allocation per Vec, not one
/// per launch.
///
/// # Examples
///
/// Lower a problem and inspect its launches — the identical value every
/// [`crate::backend::Backend`] executes and
/// [`crate::simulator::model::simulate_plan`] costs:
///
/// ```
/// use banded_svd::config::TuneParams;
/// use banded_svd::plan::LaunchPlan;
///
/// let params = TuneParams { tpb: 32, tw: 4, max_blocks: 16 };
/// let plan = LaunchPlan::for_problem(64, 8, &params);
///
/// assert!(plan.num_launches() > 0);
/// // Every launch is non-empty, and the per-launch counts tile the total.
/// let summed: usize = (0..plan.num_launches()).map(|i| plan.launch_tasks(i)).sum();
/// assert_eq!(summed, plan.total_tasks());
/// // No launch exceeds its metadata bound.
/// assert!(plan.iter_launches().all(|l| !l.is_empty()));
/// assert!(plan.max_launch_tasks() <= plan.total_tasks());
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LaunchPlan {
    pub problems: Vec<ProblemShape>,
    slots: Vec<TaskSlot>,
    /// `launch_ends[i]` = one-past-the-end slot index of launch `i`.
    launch_ends: Vec<u32>,
    /// Block capacity (MaxBlocks, clamped ≥ 1) the launches are packed
    /// under and executed/simulated with.
    pub capacity: usize,
    /// Largest stage `d` across every problem (reflector tail length).
    pub max_d: usize,
    /// Largest stage `b + d` across every problem (apply width) — the
    /// max-slot metadata workspace sizing derives from.
    pub max_bd: usize,
}

/// Algorithmic byte traffic of `count` tasks of a stage: each task's
/// right + left op reads and writes a `(1+b+d) × (d+1)` tile. This is the
/// schedule-level traffic both the executor's metrics and the simulator
/// account per launch (cache modeling then refines it per memory level).
pub fn slot_bytes(stage: &Stage, count: usize, es: usize) -> u64 {
    let tile_elems = (1 + stage.b + stage.d) * (stage.d + 1);
    4 * (tile_elems as u64) * (count as u64) * (es as u64)
}

/// Packed-tile footprint of the `count` tasks of `stage` at global cycle
/// `t`, summed — exactly Σ `task_tile_spec(..).elems()`, but in closed
/// form: within one launch, anchors strictly decrease with the sweep
/// index, so only the few tasks whose tile reaches the matrix edge (the
/// smallest sweeps) are clamped and visited individually; interior tasks
/// contribute a constant `(b+d+1)²` (or `(b+1)(b+d+1)` for the single
/// cycle-0 task, whose pivot row sits `b−d` above the anchor).
fn slot_footprint_elems(stage: &Stage, n: usize, t: usize, count: usize) -> usize {
    debug_assert!(count > 0);
    // Recover the live sweep range exactly as `tasks_at_count` does.
    let k_hi = (t / 3).min(stage.num_sweeps(n) - 1);
    let k_lo = k_hi + 1 - count;
    let (b, d) = (stage.b, stage.d);
    let span = b + d; // unclamped tile reach right of the anchor
    let mut total = 0usize;
    let mut interior = count;
    // Edge-clamped tasks have the largest anchors, i.e. the smallest
    // sweep indices — walk just those through the exact TileSpec.
    for k in k_lo..=k_hi {
        let c = t - 3 * k;
        if stage.anchor(k, c) + span <= n - 1 {
            break; // anchors only shrink with k: the rest are interior
        }
        let task = stage.task(k, c);
        total += crate::bulge::cycle::task_tile_spec(stage, &task, n).elems();
        interior -= 1;
    }
    if interior == 0 {
        return total;
    }
    // The cycle-0 task, if present, is the one at k = t/3 (the largest
    // live sweep); by the break above it is interior here.
    if t % 3 == 0 && k_hi == t / 3 {
        total += (b + 1) * (span + 1);
        interior -= 1;
    }
    total + interior * (span + 1) * (span + 1)
}

impl LaunchPlan {
    /// Lower one problem's full stage plan into a plan: one slot per
    /// non-empty launch, in schedule order.
    pub fn from_stages(n: usize, stages: Vec<Stage>, capacity: usize) -> Self {
        Self::from_stages_for(n, 0, 0, stages, capacity)
    }

    /// Lower a plan for a bandwidth-`bw` problem under `params` — the
    /// exact value [`crate::coordinator::Coordinator`] executes and
    /// [`crate::simulator::model::simulate_reduction`] costs.
    pub fn for_problem(n: usize, bw: usize, params: &TuneParams) -> Self {
        let tw = params.effective_tw(bw);
        Self::from_stages_for(n, bw, tw, stage_plan(bw, tw), params.capacity())
    }

    fn from_stages_for(
        n: usize,
        bw: usize,
        tw: usize,
        stages: Vec<Stage>,
        capacity: usize,
    ) -> Self {
        let mut stream = TaskStream::new(stages.clone(), n);
        let mut slots = Vec::new();
        let mut launch_ends = Vec::new();
        let mut tasks = 0usize;
        while let Some((si, t, count)) = stream.next_slot() {
            slots.push(TaskSlot {
                problem: 0,
                stage: si as u32,
                t: t as u32,
                count: count as u32,
            });
            launch_ends.push(slots.len() as u32);
            tasks += count;
        }
        let launches = launch_ends.len();
        let problem = ProblemShape { n, bw, tw, stages, launches, tasks };
        let mut plan = Self {
            problems: vec![problem],
            slots,
            launch_ends,
            capacity: capacity.max(1),
            max_d: 0,
            max_bd: 0,
        };
        plan.refresh_metadata();
        plan
    }

    /// Merge single-problem plans into one shared-launch plan — the batch
    /// interleaver as a *pure plan transformation*. Each shared launch
    /// pops at most one pending launch per admitted problem (so
    /// per-problem launch order is preserved exactly), packing under
    /// `capacity` according to `policy`; at most `max_coresident`
    /// problems are interleaved at a time, later ones admitted as earlier
    /// ones finish.
    pub fn merge(
        parts: &[LaunchPlan],
        capacity: usize,
        policy: PackingPolicy,
        max_coresident: usize,
    ) -> Self {
        let refs: Vec<&LaunchPlan> = parts.iter().collect();
        Self::merge_refs(&refs, capacity, policy, max_coresident)
    }

    /// [`LaunchPlan::merge`] over borrowed parts — the entry point for
    /// callers that hold their single-problem plans behind shared handles
    /// (the service plan cache hands out `Arc<LaunchPlan>`s, so merging
    /// cached parts never clones a plan).
    pub fn merge_refs(
        parts: &[&LaunchPlan],
        capacity: usize,
        policy: PackingPolicy,
        max_coresident: usize,
    ) -> Self {
        let capacity = capacity.max(1);
        let max_coresident = max_coresident.max(1);
        let problems: Vec<ProblemShape> = parts
            .iter()
            .flat_map(|p| p.problems.iter().cloned())
            .collect();
        assert_eq!(problems.len(), parts.len(), "merge expects single-problem plans");
        // Per-problem cursor into its own slot list.
        let mut cursor: Vec<usize> = vec![0; parts.len()];
        let peek = |cursor: &[usize], p: usize| -> Option<TaskSlot> {
            parts[p].slots.get(cursor[p]).copied()
        };
        let mut slots: Vec<TaskSlot> = Vec::new();
        let mut launch_ends: Vec<u32> = Vec::new();
        let mut rotation = 0usize;
        loop {
            // Admission window: the first `max_coresident` unfinished
            // problems, in batch order.
            let admitted: Vec<usize> = (0..parts.len())
                .filter(|&p| cursor[p] < parts[p].slots.len())
                .take(max_coresident)
                .collect();
            if admitted.is_empty() {
                break;
            }
            let order: Vec<usize> = match policy {
                PackingPolicy::RoundRobin => {
                    let start = rotation % admitted.len();
                    admitted[start..].iter().chain(admitted[..start].iter()).copied().collect()
                }
                PackingPolicy::GreedyFill => {
                    let mut by_size = admitted.clone();
                    by_size.sort_by_key(|&p| {
                        std::cmp::Reverse(peek(&cursor, p).map_or(0, |s| s.count))
                    });
                    by_size
                }
            };
            rotation = rotation.wrapping_add(1);

            // Select: pop at most one launch per problem while it fits
            // (the first always fits, guaranteeing progress).
            let launch_start = slots.len();
            let mut packed = 0usize;
            for &p in &order {
                let slot = match peek(&cursor, p) {
                    Some(s) => s,
                    None => continue,
                };
                let count = slot.count as usize;
                if packed > 0 && packed + count > capacity {
                    continue;
                }
                cursor[p] += 1;
                slots.push(TaskSlot { problem: p as u32, ..slot });
                packed += count;
                if packed >= capacity {
                    break;
                }
            }
            debug_assert!(slots.len() > launch_start, "shared launch must make progress");
            launch_ends.push(slots.len() as u32);
        }
        let mut plan = Self {
            problems,
            slots,
            launch_ends,
            capacity,
            max_d: 0,
            max_bd: 0,
        };
        plan.refresh_metadata();
        plan
    }

    fn refresh_metadata(&mut self) {
        self.max_d = 0;
        self.max_bd = 0;
        for p in &self.problems {
            for s in &p.stages {
                self.max_d = self.max_d.max(s.d);
                self.max_bd = self.max_bd.max(s.b + s.d);
            }
        }
    }

    /// Most tasks in any single launch (computed on demand — no
    /// production consumer pays for it on the lowering/merge path).
    pub fn max_launch_tasks(&self) -> usize {
        (0..self.num_launches()).map(|i| self.launch_tasks(i)).max().unwrap_or(0)
    }

    /// Number of launches (all non-empty by construction).
    pub fn num_launches(&self) -> usize {
        self.launch_ends.len()
    }

    /// The slots of launch `i`.
    pub fn launch(&self, i: usize) -> &[TaskSlot] {
        let start = if i == 0 { 0 } else { self.launch_ends[i - 1] as usize };
        &self.slots[start..self.launch_ends[i] as usize]
    }

    /// Iterate over the launches in execution order.
    pub fn iter_launches(&self) -> impl Iterator<Item = &[TaskSlot]> + '_ {
        (0..self.num_launches()).map(move |i| self.launch(i))
    }

    /// Tasks (thread blocks) in launch `i`.
    pub fn launch_tasks(&self, i: usize) -> usize {
        self.launch(i).iter().map(|s| s.count as usize).sum()
    }

    /// The stage a slot refers to.
    pub fn slot_stage(&self, slot: &TaskSlot) -> &Stage {
        &self.problems[slot.problem as usize].stages[slot.stage as usize]
    }

    /// Algorithmic byte traffic of launch `i` at element size `es`.
    pub fn launch_bytes(&self, i: usize, es: usize) -> u64 {
        self.launch(i)
            .iter()
            .map(|s| slot_bytes(self.slot_stage(s), s.count as usize, es))
            .sum()
    }

    /// Total cycle-tasks across the plan.
    pub fn total_tasks(&self) -> usize {
        self.problems.iter().map(|p| p.tasks).sum()
    }

    /// Packed-footprint elements of launch `i`: the sum over the
    /// launch's tasks of their packed-tile footprints
    /// ([`crate::bulge::cycle::task_tile_spec`]). This is the payload a
    /// tile-streaming backend stages per launch *instead of* whole
    /// matrices — the quantity the per-backend cost hook
    /// ([`crate::simulator::model::BackendCostModel::staged_bytes_per_elem`])
    /// charges, and always a small slice of the full storage. Computed in
    /// closed form per slot (only edge-clamped tasks are visited
    /// individually), so streaming-profile tuning stays O(slots), not
    /// O(tasks).
    pub fn launch_footprint_elems(&self, i: usize) -> usize {
        self.launch(i)
            .iter()
            .map(|slot| {
                let shape = &self.problems[slot.problem as usize];
                let stage = &shape.stages[slot.stage as usize];
                slot_footprint_elems(stage, shape.n, slot.t as usize, slot.count as usize)
            })
            .sum()
    }

    /// Launches carrying tasks from more than one problem.
    pub fn co_scheduled_launches(&self) -> usize {
        self.iter_launches().filter(|l| l.len() > 1).count()
    }

    /// Most problems co-scheduled in any single launch.
    pub fn max_problems_per_launch(&self) -> usize {
        self.iter_launches().map(|l| l.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bulge::schedule::stage_plan;

    fn params(tw: usize, mb: usize) -> TuneParams {
        TuneParams { tpb: 32, tw, max_blocks: mb }
    }

    #[test]
    fn lowering_matches_task_stream_exactly() {
        for (n, bw, tw) in [(64usize, 8usize, 4usize), (40, 6, 5), (24, 2, 1), (96, 12, 3)] {
            let plan = LaunchPlan::for_problem(n, bw, &params(tw, 16));
            let mut stream = TaskStream::new(stage_plan(bw, tw), n);
            let mut i = 0;
            while let Some((si, tasks)) = stream.next_launch() {
                let launch = plan.launch(i);
                assert_eq!(launch.len(), 1);
                assert_eq!(launch[0].stage as usize, si);
                assert_eq!(launch[0].count as usize, tasks.len());
                let stage = plan.slot_stage(&launch[0]);
                assert_eq!(stage.tasks_at(n, launch[0].t as usize), tasks);
                i += 1;
            }
            assert_eq!(plan.num_launches(), i);
            assert_eq!(plan.problems[0].launches, i);
            assert_eq!(
                plan.total_tasks(),
                stage_plan(bw, tw)
                    .iter()
                    .map(|s| crate::bulge::schedule::stage_task_count(s, n))
                    .sum::<usize>()
            );
        }
    }

    #[test]
    fn metadata_tracks_max_slot_dims() {
        let plan = LaunchPlan::for_problem(64, 8, &params(4, 16));
        // stage_plan(8, 4) = [(8,4), (4,3)]
        assert_eq!(plan.max_d, 4);
        assert_eq!(plan.max_bd, 12);
        assert!(plan.max_launch_tasks() >= 1);
        assert!(plan
            .iter_launches()
            .all(|l| l.iter().map(|s| s.count as usize).sum::<usize>() <= plan.max_launch_tasks()));
    }

    #[test]
    fn bidiagonal_problem_lowers_to_empty_plan() {
        let plan = LaunchPlan::for_problem(16, 1, &params(4, 8));
        assert_eq!(plan.num_launches(), 0);
        assert_eq!(plan.total_tasks(), 0);
        assert_eq!(plan.max_launch_tasks(), 0);
    }

    #[test]
    fn merge_preserves_per_problem_slot_order() {
        let parts: Vec<LaunchPlan> = [(48usize, 6usize), (32, 4), (40, 9)]
            .iter()
            .map(|&(n, bw)| LaunchPlan::for_problem(n, bw, &params(3, 12)))
            .collect();
        for policy in [PackingPolicy::RoundRobin, PackingPolicy::GreedyFill] {
            for cores in [1usize, 2, 8] {
                let merged = LaunchPlan::merge(&parts, 12, policy, cores);
                assert_eq!(merged.problems.len(), 3);
                for (p, part) in parts.iter().enumerate() {
                    let mine: Vec<TaskSlot> = merged
                        .slots
                        .iter()
                        .filter(|s| s.problem as usize == p)
                        .map(|s| TaskSlot { problem: 0, ..*s })
                        .collect();
                    assert_eq!(mine, part.slots, "problem {p} ({policy:?}, cores {cores})");
                }
                assert_eq!(merged.total_tasks(), parts.iter().map(|p| p.total_tasks()).sum());
            }
        }
    }

    #[test]
    fn merge_respects_capacity_unless_single_slot() {
        let parts: Vec<LaunchPlan> = (0..4)
            .map(|_| LaunchPlan::for_problem(72, 8, &params(4, 6)))
            .collect();
        let merged = LaunchPlan::merge(&parts, 6, PackingPolicy::GreedyFill, 8);
        for i in 0..merged.num_launches() {
            let launch = merged.launch(i);
            if launch.len() > 1 {
                assert!(merged.launch_tasks(i) <= 6, "launch {i} overflows");
            }
        }
    }

    #[test]
    fn serial_merge_concatenates() {
        let parts: Vec<LaunchPlan> = [(48usize, 6usize), (32, 4)]
            .iter()
            .map(|&(n, bw)| LaunchPlan::for_problem(n, bw, &params(3, 16)))
            .collect();
        let merged = LaunchPlan::merge(&parts, 16, PackingPolicy::RoundRobin, 1);
        assert_eq!(merged.co_scheduled_launches(), 0);
        assert_eq!(merged.max_problems_per_launch(), 1);
        assert_eq!(
            merged.num_launches(),
            parts.iter().map(|p| p.num_launches()).sum::<usize>()
        );
        // With max_coresident = 1 problem 0 runs to completion first.
        let first: Vec<u32> = merged.slots[..parts[0].slots.len()]
            .iter()
            .map(|s| s.problem)
            .collect();
        assert!(first.iter().all(|&p| p == 0));
    }

    #[test]
    fn merge_refs_is_merge() {
        let parts: Vec<LaunchPlan> = [(48usize, 6usize), (32, 4), (40, 9)]
            .iter()
            .map(|&(n, bw)| LaunchPlan::for_problem(n, bw, &params(3, 12)))
            .collect();
        let refs: Vec<&LaunchPlan> = parts.iter().collect();
        for policy in [PackingPolicy::RoundRobin, PackingPolicy::GreedyFill] {
            assert_eq!(
                LaunchPlan::merge(&parts, 12, policy, 2),
                LaunchPlan::merge_refs(&refs, 12, policy, 2),
                "{policy:?}"
            );
        }
    }

    #[test]
    fn merge_of_nothing_is_empty() {
        let merged = LaunchPlan::merge(&[], 8, PackingPolicy::RoundRobin, 4);
        assert_eq!(merged.num_launches(), 0);
        assert_eq!(merged.problems.len(), 0);
        assert_eq!(merged.total_tasks(), 0);
    }

    #[test]
    fn launch_footprints_match_brute_force_tile_specs() {
        use crate::bulge::cycle::task_tile_spec;
        // The closed form must equal Σ task_tile_spec(..).elems() exactly,
        // including edge-clamped and cycle-0 tasks, across shapes where
        // launches mix all three task kinds.
        for (n, bw, tw) in [(96usize, 8usize, 4usize), (40, 6, 5), (24, 2, 1), (77, 9, 3)] {
            let plan = LaunchPlan::for_problem(n, bw, &params(tw, 16));
            let full_storage_elems = (bw + 2 * tw + 1) * n; // ld × n
            for i in 0..plan.num_launches() {
                let fp = plan.launch_footprint_elems(i);
                let brute: usize = plan
                    .launch(i)
                    .iter()
                    .map(|s| {
                        let st = plan.slot_stage(s);
                        st.tasks_at(n, s.t as usize)
                            .iter()
                            .map(|task| task_tile_spec(st, task, n).elems())
                            .sum::<usize>()
                    })
                    .sum();
                assert_eq!(fp, brute, "n={n} bw={bw} tw={tw} launch {i}");
                // Non-empty launches stage a non-empty, sub-matrix footprint.
                assert!(fp > 0, "launch {i}: empty footprint");
                assert!(fp < full_storage_elems, "launch {i}: footprint not memory-aware");
            }
        }
    }

    #[test]
    fn launch_bytes_are_positive_and_scale_with_es() {
        let plan = LaunchPlan::for_problem(64, 8, &params(4, 16));
        for i in 0..plan.num_launches() {
            let b4 = plan.launch_bytes(i, 4);
            let b8 = plan.launch_bytes(i, 8);
            assert!(b4 > 0);
            assert_eq!(b8, 2 * b4);
        }
    }
}
