//! Per-plan reflector log — the record/replay seam singular vectors
//! ride through the plan IR.
//!
//! Executing a [`LaunchPlan`] forms two Householder reflectors per
//! cycle-task: the **right** (column-combining, V-side) one and the
//! **left** (row-combining, U-side) one, both with tail length
//! `dd = min(stage.d, n−1−anchor)`. [`ReflectorLog`] reserves one flat
//! per-problem f64 arena for those values, *position-indexed* by the
//! problem's plan-order task ordinal: launches in plan order, slots in
//! launch order, [`Stage::tasks_at`](crate::bulge::schedule::Stage)
//! order within a slot. Executors write each record exactly once at
//! its precomputed offset, so concurrent tasks of a launch touch
//! disjoint arena ranges and every native backend — sequential,
//! threadpool, SIMD — fills identical bits (the same bitwise guarantee
//! the band storage itself carries; see `docs/backends.md`).
//!
//! Record layout per task: `[τ_r, v_r₁ .. v_r_dd, τ_l, v_l₁ .. v_l_dd]`
//! (f64, converted exactly from the working precision). A `τ` of zero
//! marks an identity reflector; its tail slots then hold whatever was
//! gathered and are ignored on replay (`apply_reflector_*`
//! early-returns on `τ == 0`).

use crate::bulge::schedule::CycleTask;
use crate::error::{Error, Result};
use crate::plan::LaunchPlan;

/// One problem's recorded reflectors: a flat arena plus per-task record
/// bounds (`offsets[t] .. offsets[t+1]`).
#[derive(Clone, Debug)]
struct ProblemReflectors {
    offsets: Vec<usize>,
    data: Vec<f64>,
}

/// The reflector record of every cycle-task of a plan, per problem —
/// allocated up-front from the plan alone ([`ReflectorLog::for_plan`]),
/// filled by `Backend::execute_logged`, replayed by
/// [`crate::pipeline::vectors::accumulate_panels`].
#[derive(Clone, Debug)]
pub struct ReflectorLog {
    problems: Vec<ProblemReflectors>,
}

impl ReflectorLog {
    /// Size a log for `plan`: walk the plan exactly as executors do and
    /// reserve `2·(dd+1)` f64 per task. O(total tasks), data zeroed.
    pub fn for_plan(plan: &LaunchPlan) -> Self {
        let mut offsets: Vec<Vec<usize>> =
            plan.problems.iter().map(|_| vec![0usize]).collect();
        let mut tasks: Vec<CycleTask> = Vec::new();
        for li in 0..plan.num_launches() {
            for slot in plan.launch(li) {
                let p = slot.problem as usize;
                let shape = &plan.problems[p];
                let stage = &shape.stages[slot.stage as usize];
                tasks.clear();
                stage.tasks_at_into(shape.n, slot.t as usize, &mut tasks);
                for task in &tasks {
                    let jd = (task.anchor + stage.d).min(shape.n - 1);
                    let dd = jd - task.anchor;
                    let prev = *offsets[p].last().unwrap();
                    offsets[p].push(prev + 2 * (dd + 1));
                }
            }
        }
        let problems = offsets
            .into_iter()
            .map(|offs| {
                let len = *offs.last().unwrap();
                ProblemReflectors { offsets: offs, data: vec![0.0; len] }
            })
            .collect();
        Self { problems }
    }

    /// Problems the log covers (`== plan.problems.len()`).
    pub fn num_problems(&self) -> usize {
        self.problems.len()
    }

    /// Tasks recorded for plan problem `p`.
    pub fn tasks(&self, p: usize) -> usize {
        self.problems[p].offsets.len() - 1
    }

    /// The recorded (right, left) reflectors of task `ordinal` of
    /// problem `p`, each as `[τ, v₁ .. v_dd]`.
    pub fn task(&self, p: usize, ordinal: usize) -> (&[f64], &[f64]) {
        let pr = &self.problems[p];
        let rec = &pr.data[pr.offsets[ordinal]..pr.offsets[ordinal + 1]];
        rec.split_at(rec.len() / 2)
    }

    /// Validate this log was sized for `plan` — the prologue every
    /// `execute_logged` runs before handing out arena views.
    pub fn check_plan(&self, plan: &LaunchPlan) -> Result<()> {
        if self.problems.len() != plan.problems.len() {
            return Err(Error::Config(format!(
                "reflector log covers {} problems but the plan has {}",
                self.problems.len(),
                plan.problems.len()
            )));
        }
        for (p, shape) in plan.problems.iter().enumerate() {
            if self.tasks(p) != shape.tasks {
                return Err(Error::Config(format!(
                    "reflector log problem {p} has {} task records but the plan \
                     schedules {} tasks — log built for a different plan",
                    self.tasks(p),
                    shape.tasks
                )));
            }
        }
        Ok(())
    }

    /// Raw arena view for problem `p`, handed to an executor for the
    /// duration of one `execute_logged` call (which holds the log
    /// exclusively, so the view cannot outlive the arena).
    pub(crate) fn view(&mut self, p: usize) -> LogView {
        let pr = &mut self.problems[p];
        LogView {
            data: pr.data.as_mut_ptr(),
            offsets: pr.offsets.as_ptr(),
            tasks: pr.offsets.len() - 1,
        }
    }
}

/// A raw, `Send + Sync` view over one problem's reflector arena, used by
/// the launch-level parallel executor. Safety rests on ordinal
/// disjointness: the plan assigns every task a unique per-problem
/// ordinal, so concurrent tasks write disjoint records — the same
/// argument [`crate::bulge::cycle::SharedBanded`] makes for the band.
#[derive(Copy, Clone, Debug)]
pub(crate) struct LogView {
    data: *mut f64,
    offsets: *const usize,
    tasks: usize,
}

unsafe impl Send for LogView {}
unsafe impl Sync for LogView {}

impl LogView {
    /// The mutable (right, left) record slices of task `ordinal`.
    ///
    /// # Safety
    /// The parent [`ReflectorLog`] must outlive every use of the
    /// returned slices, and no two concurrent callers may pass the same
    /// `ordinal` (within one plan launch every task has a distinct
    /// ordinal, and launches are barrier-ordered).
    pub(crate) unsafe fn task_mut<'a>(&self, ordinal: usize) -> (&'a mut [f64], &'a mut [f64]) {
        debug_assert!(ordinal < self.tasks, "ordinal {ordinal} out of {}", self.tasks);
        let lo = *self.offsets.add(ordinal);
        let hi = *self.offsets.add(ordinal + 1);
        let half = (hi - lo) / 2;
        let right = std::slice::from_raw_parts_mut(self.data.add(lo), half);
        let left = std::slice::from_raw_parts_mut(self.data.add(lo + half), half);
        (right, left)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PackingPolicy, TuneParams};

    fn params(tw: usize, mb: usize) -> TuneParams {
        TuneParams { tpb: 32, tw, max_blocks: mb }
    }

    #[test]
    fn log_reserves_one_record_per_scheduled_task() {
        for (n, bw, tw) in [(64usize, 8usize, 4usize), (40, 6, 5), (24, 2, 1)] {
            let plan = LaunchPlan::for_problem(n, bw, &params(tw, 16));
            let log = ReflectorLog::for_plan(&plan);
            assert_eq!(log.num_problems(), 1);
            assert_eq!(log.tasks(0), plan.total_tasks());
            assert!(log.check_plan(&plan).is_ok());
            // Every record is non-degenerate (dd ≥ 1 — anchors stop at
            // n−2) and symmetric between the two sides.
            for t in 0..log.tasks(0) {
                let (right, left) = log.task(0, t);
                assert_eq!(right.len(), left.len());
                assert!(right.len() >= 2, "task {t}: record too small");
            }
        }
    }

    #[test]
    fn merged_plan_logs_follow_per_problem_task_counts() {
        let parts: Vec<LaunchPlan> = [(48usize, 6usize), (32, 4), (40, 9)]
            .iter()
            .map(|&(n, bw)| LaunchPlan::for_problem(n, bw, &params(3, 12)))
            .collect();
        let merged = LaunchPlan::merge(&parts, 12, PackingPolicy::RoundRobin, 2);
        let log = ReflectorLog::for_plan(&merged);
        assert_eq!(log.num_problems(), 3);
        for (p, part) in parts.iter().enumerate() {
            assert_eq!(log.tasks(p), part.total_tasks(), "problem {p}");
        }
        assert!(log.check_plan(&merged).is_ok());
        // A log sized for a different plan is rejected.
        assert!(log.check_plan(&parts[0]).is_err());
    }

    #[test]
    fn views_hand_out_disjoint_record_slices() {
        let plan = LaunchPlan::for_problem(40, 6, &params(3, 8));
        let mut log = ReflectorLog::for_plan(&plan);
        let view = log.view(0);
        let tasks = plan.total_tasks();
        // SAFETY: distinct ordinals, log outlives the uses below.
        unsafe {
            for t in 0..tasks {
                let (right, left) = view.task_mut(t);
                for v in right.iter_mut().chain(left.iter_mut()) {
                    *v = t as f64 + 1.0;
                }
            }
        }
        for t in 0..tasks {
            let (right, left) = log.task(0, t);
            assert!(right.iter().chain(left.iter()).all(|&v| v == t as f64 + 1.0));
        }
    }
}
