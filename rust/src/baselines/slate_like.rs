//! SLATE-style baseline.
//!
//! SLATE (as of the versions the paper benchmarks) executes stage 2 on
//! the CPU with a sweep-major, whole-bandwidth algorithm: each sweep
//! chases its bulge across the entire matrix before the next sweep
//! starts, with large blocked transforms but no inter-sweep pipelining.
//! We model that behaviour: full-bandwidth tilewidth (d = bw−1 in one
//! stage), strictly sweep-major, single-threaded.

use crate::banded::storage::Banded;
use crate::bulge::cycle::{exec_cycle, CycleWorkspace};
use crate::bulge::schedule::Stage;
use crate::scalar::Scalar;

/// Reduce `a` (bandwidth `bw`) to bidiagonal, whole bandwidth at once,
/// sweep-major. Storage: `kd_sub ≥ bw−1`, `kd_super ≥ 2·bw−1`.
pub fn slate_like_reduce<T: Scalar>(a: &mut Banded<T>, bw: usize) {
    if bw <= 1 {
        return;
    }
    let d = bw - 1;
    assert!(
        a.kd_sub() >= d && a.kd_super() >= bw + d,
        "storage too small: need kd_sub ≥ {d}, kd_super ≥ {}",
        bw + d
    );
    let n = a.n();
    let stage = Stage::new(bw, d);
    let mut ws = CycleWorkspace::new(&stage);
    for k in 0..stage.num_sweeps(n) {
        for c in 0..=stage.cmax(n, k) {
            exec_cycle(a, &stage, &stage.task(k, c), &mut ws);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn reduces_to_bidiagonal() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let (n, bw) = (40, 7);
        let mut a = random_banded::<f64>(n, bw, bw - 1, &mut rng);
        let before = a.fro_norm();
        slate_like_reduce(&mut a, bw);
        assert_eq!(a.max_off_band(1), 0.0);
        assert!((a.fro_norm() - before).abs() < 1e-10 * before);
    }

    #[test]
    fn bidiagonal_input_is_untouched() {
        let n = 10;
        let mut a = Banded::<f64>::for_reduction(n, 1, 1);
        for i in 0..n {
            a.set(i, i, 2.0);
            if i + 1 < n {
                a.set(i, i + 1, 1.0);
            }
        }
        let before = a.clone();
        slate_like_reduce(&mut a, 1);
        assert_eq!(a, before);
    }
}
