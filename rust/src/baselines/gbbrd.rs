//! LAPACK-`gbbrd`-style baseline: reduce the whole bandwidth at once with
//! elementary (length-2) transforms, chasing each fill element
//! individually to the matrix edge. No bandwidth tiling, no sweep
//! pipelining — the classical sequential algorithm that the paper's
//! tiled, parallel formulation is measured against.

use crate::banded::storage::Banded;
use crate::bulge::cycle::{exec_cycle, CycleWorkspace};
use crate::bulge::schedule::Stage;
use crate::scalar::Scalar;

/// Reduce `a` (bandwidth `bw`) to bidiagonal using single-element chases
/// (tilewidth 1, sweep-major, element-at-a-time). Storage needs
/// `kd_sub ≥ 1`, `kd_super ≥ bw + 1`.
pub fn gbbrd_reduce<T: Scalar>(a: &mut Banded<T>, bw: usize) {
    assert!(a.kd_sub() >= 1 && a.kd_super() >= bw + 1);
    let n = a.n();
    // Successively peel ONE diagonal at a time: the no-tiling limit
    // (tw = 1 at every width), which maximizes passes over the matrix —
    // exactly the memory behaviour gbbrd exhibits.
    let mut b = bw;
    while b > 1 {
        let stage = Stage::new(b, 1);
        let mut ws = CycleWorkspace::new(&stage);
        for k in 0..stage.num_sweeps(n) {
            for c in 0..=stage.cmax(n, k) {
                exec_cycle(a, &stage, &stage.task(k, c), &mut ws);
            }
        }
        b -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn reduces_to_bidiagonal() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (n, bw) = (32, 6);
        let mut a = random_banded::<f64>(n, bw, 1, &mut rng);
        let before = a.fro_norm();
        gbbrd_reduce(&mut a, bw);
        assert_eq!(a.max_off_band(1), 0.0);
        assert!((a.fro_norm() - before).abs() < 1e-10 * before);
    }

    #[test]
    fn same_singular_values_as_tiled_reduction() {
        use crate::config::TuneParams;
        use crate::pipeline::stage3::bidiagonal_singular_values;
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (n, bw) = (28, 5);
        let base = random_banded::<f64>(n, bw, 4, &mut rng);
        // gbbrd path.
        let dense = base.to_dense();
        let mut a1 = Banded::<f64>::from_dense(&dense, n, bw, 1);
        gbbrd_reduce(&mut a1, bw);
        let (d1, e1) = a1.bidiagonal();
        let s1 = bidiagonal_singular_values(
            &d1.iter().map(|v| v.to_f64()).collect::<Vec<_>>(),
            &e1.iter().map(|v| v.to_f64()).collect::<Vec<_>>(),
        );
        // Tiled path.
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 192 };
        let mut a2 = Banded::<f64>::from_dense(&dense, n, bw, 4);
        let red = crate::bulge::reduce_to_bidiagonal(&mut a2, bw, &params);
        let s2 = bidiagonal_singular_values(&red.diag, &red.superdiag);
        for (x, y) in s1.iter().zip(s2.iter()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }
}
