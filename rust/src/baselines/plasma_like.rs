//! PLASMA-style baseline: multicore, task-coalesced bulge chasing
//! (Haidar, Ltaief, Luszczek, Dongarra 2012).
//!
//! PLASMA pipelines sweeps across CPU cores with *coarse* tasks — several
//! consecutive cycles of one sweep are coalesced into a task to amortize
//! scheduling overhead, at the cost of a longer pipeline ramp. We model
//! that: whole-bandwidth reduction (no tiling), launch-level parallelism
//! with the coalescing factor `grouping`, executed on the thread pool.

use crate::banded::storage::Banded;
use crate::bulge::cycle::{exec_cycle_shared, CycleWorkspace, SharedBanded};
use crate::bulge::schedule::Stage;
use crate::scalar::Scalar;
use crate::util::threadpool::ThreadPool;

/// Reduce `a` (bandwidth `bw`) to bidiagonal, whole bandwidth at once,
/// with sweep-pipelined multicore execution. `grouping` = cycles
/// coalesced per task (PLASMA's task-coalescing knob; 1 = finest).
/// Storage: `kd_sub ≥ bw−1`, `kd_super ≥ 2·bw−1`.
pub fn plasma_like_reduce<T: Scalar>(
    a: &mut Banded<T>,
    bw: usize,
    pool: &ThreadPool,
    grouping: usize,
) {
    if bw <= 1 {
        return;
    }
    let d = bw - 1;
    assert!(a.kd_sub() >= d && a.kd_super() >= bw + d);
    let n = a.n();
    let stage = Stage::new(bw, d);
    let g = grouping.max(1);
    let view = SharedBanded::new(a);
    // Launch-major schedule over *groups*: a super-launch `tg` executes
    // cycles [g·c0, g·c0+g) of each live sweep, sweeps separated by 3
    // super-cycles (which implies 3·g plain cycles — coarser, therefore a
    // longer pipeline, exactly PLASMA's trade-off).
    let ns = stage.num_sweeps(n);
    if ns == 0 {
        return;
    }
    let groups_per_sweep = |k: usize| (stage.cmax(n, k) / g) + 1;
    let total_super = 3 * (ns - 1) + groups_per_sweep(ns - 1);
    for tg in 0..total_super {
        // Live sweeps at super-cycle tg.
        let mut tasks: Vec<(usize, usize)> = Vec::new(); // (sweep, group)
        let k_hi = (tg / 3).min(ns - 1);
        for k in (0..=k_hi).rev() {
            if tg < 3 * k {
                continue;
            }
            let grp = tg - 3 * k;
            if grp < groups_per_sweep(k) {
                tasks.push((k, grp));
            } else if grp > groups_per_sweep(k) + 2 {
                break; // all earlier sweeps finished long ago
            }
        }
        if tasks.is_empty() {
            continue;
        }
        let chunks = tasks.len().min(pool.len().max(1));
        pool.for_each_chunk(tasks.len(), chunks, |range| {
            let mut ws = CycleWorkspace::new(&stage);
            for idx in range.clone() {
                let (k, grp) = tasks[idx];
                let cmax = stage.cmax(n, k);
                for c in (grp * g)..((grp + 1) * g).min(cmax + 1) {
                    // SAFETY: groups of different sweeps are ≥ 3·g cycles
                    // apart, a fortiori ≥ 3 cycles ⇒ disjoint rectangles
                    // (same argument as the fine schedule, with larger
                    // separation).
                    unsafe { exec_cycle_shared(&view, &stage, &stage.task(k, c), &mut ws) };
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn reduces_to_bidiagonal_and_matches_sequential() {
        let pool = ThreadPool::new(4);
        let mut rng = Xoshiro256::seed_from_u64(4);
        for grouping in [1usize, 2, 4] {
            let (n, bw) = (48, 6);
            let mut a = random_banded::<f64>(n, bw, bw - 1, &mut rng);
            let mut reference = a.clone();
            crate::baselines::slate_like::slate_like_reduce(&mut reference, bw);
            plasma_like_reduce(&mut a, bw, &pool, grouping);
            assert_eq!(a.max_off_band(1), 0.0, "grouping={grouping}");
            // Same reflector sequence ⇒ bitwise-identical bidiagonal.
            assert_eq!(a, reference, "grouping={grouping}");
        }
    }

    #[test]
    fn group_separation_is_conflict_free() {
        // Stress: many threads, small matrix, fine grouping.
        let pool = ThreadPool::new(8);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (n, bw) = (96, 4);
        let mut a = random_banded::<f64>(n, bw, bw - 1, &mut rng);
        let mut reference = a.clone();
        crate::baselines::slate_like::slate_like_reduce(&mut reference, bw);
        plasma_like_reduce(&mut a, bw, &pool, 1);
        assert_eq!(a, reference);
    }
}
