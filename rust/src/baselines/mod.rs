//! CPU baselines for the Fig. 6 comparison.
//!
//! - [`gbbrd`]       — LAPACK-gbbrd-style one-shot reduction: chase each
//!   element with Givens-like 2×2 Householder steps, no tiling, no
//!   parallelism. Represents the classical reference algorithm.
//! - [`slate_like`]  — coarse-grained single-pass reduction in the style
//!   SLATE executes stage 2 (sweep-major, whole-bandwidth tasks, single
//!   thread per sweep chain).
//! - [`plasma_like`] — task-coalesced multicore bulge chasing in the
//!   style of PLASMA/Haidar 2012: groups of sweeps pipelined across CPU
//!   threads with coarse tasks.

pub mod gbbrd;
pub mod plasma_like;
pub mod slate_like;

pub use gbbrd::gbbrd_reduce;
pub use plasma_like::plasma_like_reduce;
pub use slate_like::slate_like_reduce;
