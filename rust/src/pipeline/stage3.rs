//! Stage 3: singular values of an upper-bidiagonal matrix.
//!
//! Primary method: bisection on the Golub–Kahan tridiagonal
//! `TGK = perm([0 Bᵀ; B 0])` — symmetric tridiagonal with zero diagonal
//! and off-diagonal `(d₁, e₁, d₂, e₂, …, d_n)`, whose eigenvalues are
//! `±σ_i`. Bisection with Sturm counts on a zero-diagonal tridiagonal
//! computes every σ to high *relative* accuracy (Demmel–Kahan), which is
//! what makes it a trustworthy replacement for LAPACK BDSDC in the
//! Fig. 3 protocol. The paper runs this stage in FP64; so do we.

use crate::util::threadpool::ThreadPool;

/// Off-diagonal of the Golub–Kahan tridiagonal: interleave(d, e).
fn golub_kahan_offdiag(d: &[f64], e: &[f64]) -> Vec<f64> {
    let n = d.len();
    assert_eq!(e.len() + 1, n, "superdiagonal must have n−1 entries");
    let mut off = Vec::with_capacity(2 * n - 1);
    for i in 0..n {
        off.push(d[i]);
        if i + 1 < n {
            off.push(e[i]);
        }
    }
    off
}

/// Sturm count: number of eigenvalues of the zero-diagonal symmetric
/// tridiagonal with off-diagonal `off` that are strictly less than `x`.
/// `pivmin` guards against division blow-up (LAPACK-style).
fn sturm_count(off: &[f64], x: f64, pivmin: f64) -> usize {
    let m = off.len() + 1;
    let mut count = 0usize;
    let mut q = -x; // diagonal is zero
    if q < 0.0 {
        count += 1;
    }
    for &b in off {
        if q.abs() < pivmin {
            q = if q < 0.0 { -pivmin } else { pivmin };
        }
        q = -x - (b * b) / q;
        if q < 0.0 {
            count += 1;
        }
    }
    debug_assert_eq!(m, off.len() + 1);
    count
}

/// All singular values of the bidiagonal (d, e), descending, by bisection
/// on the Golub–Kahan form. O(n² log(1/ε)).
pub fn bidiagonal_singular_values(d: &[f64], e: &[f64]) -> Vec<f64> {
    bidiagonal_singular_values_impl(d, e, None)
}

/// Parallel variant: the per-σ bisections are independent.
pub fn bidiagonal_singular_values_parallel(
    d: &[f64],
    e: &[f64],
    pool: &ThreadPool,
) -> Vec<f64> {
    bidiagonal_singular_values_impl(d, e, Some(pool))
}

fn bidiagonal_singular_values_impl(
    d: &[f64],
    e: &[f64],
    pool: Option<&ThreadPool>,
) -> Vec<f64> {
    let n = d.len();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![d[0].abs()];
    }
    let off = golub_kahan_offdiag(d, e);
    // Gershgorin-style bound on the TGK spectrum: |λ| ≤ max row sum.
    let mut bound = 0.0f64;
    for i in 0..off.len() + 1 {
        let left = if i > 0 { off[i - 1].abs() } else { 0.0 };
        let right = if i < off.len() { off[i].abs() } else { 0.0 };
        bound = bound.max(left + right);
    }
    if bound == 0.0 {
        return vec![0.0; n];
    }
    bound *= 1.0 + 1e-12;
    let max_off = off.iter().fold(0.0f64, |m, &b| m.max(b.abs()));
    let pivmin = (f64::EPSILON * max_off * max_off).max(f64::MIN_POSITIVE);

    let compute_k = |k: usize| -> f64 {
        // σ_k (0-indexed, descending): bisect on x > 0. For x > 0,
        // #(eigs < x) = n + #(σ < x); σ_k is the (n−k)-th smallest σ:
        // invariant: count(hi) ≥ n + (n−k), count(lo) < n + (n−k).
        let want = n + (n - 1 - k) + 1; // count ≥ want ⇒ σ_k < x
        let (mut lo, mut hi) = (0.0f64, bound);
        // ~60 iterations: bound/2^60 ≪ any representable σ of interest;
        // stop earlier on relative convergence.
        for _ in 0..120 {
            let mid = 0.5 * (lo + hi);
            if mid <= lo || mid >= hi {
                break;
            }
            if sturm_count(&off, mid, pivmin) >= want {
                hi = mid;
            } else {
                lo = mid;
            }
            if (hi - lo) <= 2.0 * f64::EPSILON * hi.max(1e-300) {
                break;
            }
        }
        0.5 * (lo + hi)
    };

    let mut out = vec![0.0f64; n];
    match pool {
        Some(pool) if n >= 32 => {
            use std::sync::atomic::{AtomicU64, Ordering};
            let bits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.for_each_index(n, |k| {
                bits[k].store(compute_k(k).to_bits(), Ordering::Relaxed);
            });
            for (o, b) in out.iter_mut().zip(bits.iter()) {
                *o = f64::from_bits(b.load(Ordering::Relaxed));
            }
        }
        _ => {
            for (k, o) in out.iter_mut().enumerate() {
                *o = compute_k(k);
            }
        }
    }
    out
}

/// Relative error metric of the paper's Fig. 3: ‖σ̂ − σ‖₂ / ‖σ‖₂.
pub fn relative_sv_error(computed: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(computed.len(), truth.len());
    let num: f64 = computed
        .iter()
        .zip(truth.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den: f64 = truth.iter().map(|b| b * b).sum::<f64>().sqrt();
    if den == 0.0 {
        num
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_bidiagonal;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn diagonal_matrix_singular_values_are_abs_diag() {
        let d = vec![3.0, -1.0, 2.0, 0.5];
        let e = vec![0.0, 0.0, 0.0];
        let sv = bidiagonal_singular_values(&d, &e);
        assert_eq!(sv.len(), 4);
        let expect = [3.0, 2.0, 1.0, 0.5];
        for (a, b) in sv.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-12, "{sv:?}");
        }
    }

    #[test]
    fn two_by_two_closed_form() {
        // B = [[a, b], [0, c]]: σ² are eigenvalues of BᵀB.
        let (a, b, c) = (2.0f64, 1.0f64, 3.0f64);
        let sv = bidiagonal_singular_values(&[a, c], &[b]);
        // Closed form via BᵀB = [[a², ab], [ab, b²+c²]].
        let tr = a * a + b * b + c * c;
        let det = (a * c) * (a * c);
        let disc = (tr * tr - 4.0 * det).sqrt();
        let s1 = ((tr + disc) / 2.0).sqrt();
        let s2 = ((tr - disc) / 2.0).sqrt();
        assert!((sv[0] - s1).abs() < 1e-12, "{} vs {s1}", sv[0]);
        assert!((sv[1] - s2).abs() < 1e-12, "{} vs {s2}", sv[1]);
    }

    #[test]
    fn values_are_sorted_descending_and_nonnegative() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let (d, e) = random_bidiagonal(40, &mut rng);
        let sv = bidiagonal_singular_values(&d, &e);
        assert!(sv.windows(2).all(|w| w[0] >= w[1] - 1e-12), "{sv:?}");
        assert!(sv.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn frobenius_identity_holds() {
        // Σσ² = ‖B‖_F².
        let mut rng = Xoshiro256::seed_from_u64(4);
        let (d, e) = random_bidiagonal(30, &mut rng);
        let sv = bidiagonal_singular_values(&d, &e);
        let ssq: f64 = sv.iter().map(|s| s * s).sum();
        let fro: f64 =
            d.iter().map(|x| x * x).sum::<f64>() + e.iter().map(|x| x * x).sum::<f64>();
        assert!((ssq - fro).abs() < 1e-9 * fro, "{ssq} vs {fro}");
    }

    #[test]
    fn splitting_with_zero_superdiagonal() {
        // e contains an exact zero: matrix decouples into two blocks.
        let d = vec![1.0, 2.0, 5.0, 4.0];
        let e = vec![0.5, 0.0, 0.25];
        let sv = bidiagonal_singular_values(&d, &e);
        // Compare against concatenated 2×2 blocks.
        let block1 = bidiagonal_singular_values(&[1.0, 2.0], &[0.5]);
        let block2 = bidiagonal_singular_values(&[5.0, 4.0], &[0.25]);
        let mut expect = [block1, block2].concat();
        expect.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for (a, b) in sv.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-11, "{sv:?} vs {expect:?}");
        }
    }

    #[test]
    fn tiny_singular_values_computed_with_relative_accuracy() {
        // Graded bidiagonal: σ_min ~ 1e-12 must come out with small
        // *relative* error (the Demmel–Kahan property of GK bisection).
        let d = vec![1.0, 1e-6, 1e-12];
        let e = vec![0.0, 0.0];
        let sv = bidiagonal_singular_values(&d, &e);
        assert!((sv[2] - 1e-12).abs() / 1e-12 < 1e-10, "{:?}", sv);
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (d, e) = random_bidiagonal(64, &mut rng);
        let s1 = bidiagonal_singular_values(&d, &e);
        let s2 = bidiagonal_singular_values_parallel(&d, &e, &pool);
        assert_eq!(s1, s2);
    }

    #[test]
    fn relative_error_metric() {
        assert_eq!(relative_sv_error(&[1.0, 1.0], &[1.0, 1.0]), 0.0);
        let e = relative_sv_error(&[1.1], &[1.0]);
        assert!((e - 0.1).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(bidiagonal_singular_values(&[], &[]).is_empty());
        assert_eq!(bidiagonal_singular_values(&[-2.5], &[]), vec![2.5]);
    }
}
