//! Singular-vector accumulation — replay a [`ReflectorLog`] and the
//! Demmel–Kahan rotation stream into dense `U` / `Vᵀ` panels.
//!
//! The band stage records two Householder reflectors per cycle-task
//! (see [`crate::plan::reflectors`]); the bidiagonal stage emits a
//! Givens rotation stream ([`dk_qr_factor`]). Composing both:
//!
//! ```text
//! A  =  U_band · B · Vᵀ_band          (bulge chasing, replayed here)
//! B  =  U_qr   · Σ · Vᵀ_qr            (Demmel–Kahan, rotation sink)
//! A  =  (U_band U_qr) · Σ · (Vᵀ_qr Vᵀ_band)
//! ```
//!
//! [`accumulate_panels`] walks the plan in the same launch → slot →
//! task order the executors (and [`ReflectorLog::for_plan`]) do, so the
//! per-problem task ordinal lines up with the recorded arena by
//! construction. A task's **right** reflector spans rows
//! `anchor ..= anchor+dd` of `Vᵀ` (`Vᵀ ← H·Vᵀ`), its **left** reflector
//! columns `anchor ..= anchor+dd` of `U` (`U ← U·H`). Replay order
//! within a launch is irrelevant: concurrent tasks touch disjoint index
//! ranges, so their factors commute — plan order is one valid
//! serialization, the same argument that makes the chase itself
//! deterministic.
//!
//! Everything here is f64 regardless of the working precision: the log
//! stores exact f64 conversions, so the panels carry no extra rounding
//! beyond what the band stage itself committed.

use crate::backend::{execute_reduction_logged, AsBandStorageMut, Backend};
use crate::banded::dense::Dense;
use crate::banded::storage::Banded;
use crate::bulge::schedule::CycleTask;
use crate::config::TuneParams;
use crate::error::Result;
use crate::householder::{apply_reflector_cols, apply_reflector_rows};
use crate::pipeline::dk_qr::{dk_qr_factor, GivensSide};
use crate::plan::{LaunchPlan, ReflectorLog};
use crate::scalar::Scalar;

/// A full small-dense SVD triple: `A = U · diag(sv) · Vᵀ` with `sv`
/// descending and `U`, `Vᵀ` orthogonal (n×n, f64).
#[derive(Clone, Debug)]
pub struct SvdVectors {
    /// Singular values, descending.
    pub sv: Vec<f64>,
    /// Left singular vectors, one per column.
    pub u: Dense<f64>,
    /// Right singular vectors, one per **row** (the transpose).
    pub vt: Dense<f64>,
}

/// Replay problem `problem`'s recorded reflectors into `u` and `vt`
/// (usually identities on entry), in plan order. After this,
/// `A = u · B · vt` where `A` was the problem's input band and `B` the
/// chased (bidiagonal) result.
///
/// Panics (debug) if the log was not filled for exactly this plan —
/// callers get it from [`execute_reduction_logged`], which guarantees
/// the pairing.
pub fn accumulate_panels(
    plan: &LaunchPlan,
    log: &ReflectorLog,
    problem: usize,
    u: &mut Dense<f64>,
    vt: &mut Dense<f64>,
) {
    let shape = &plan.problems[problem];
    let n = shape.n;
    debug_assert_eq!((u.rows, u.cols, vt.rows, vt.cols), (n, n, n, n));
    let mut ordinal = 0usize;
    let mut tasks: Vec<CycleTask> = Vec::new();
    for li in 0..plan.num_launches() {
        for slot in plan.launch(li) {
            if slot.problem as usize != problem {
                continue;
            }
            let stage = &shape.stages[slot.stage as usize];
            tasks.clear();
            stage.tasks_at_into(n, slot.t as usize, &mut tasks);
            for task in &tasks {
                let (right, left) = log.task(problem, ordinal);
                // Right reflector: A ← A·H, so Vᵀ ← H·Vᵀ (rows
                // anchor..=anchor+dd, every column).
                apply_reflector_rows(vt, right[0], &right[1..], task.anchor, 0, n - 1);
                // Left reflector: A ← H·A, so U ← U·H (columns
                // anchor..=anchor+dd, every row).
                apply_reflector_cols(u, left[0], &left[1..], task.anchor, 0, n - 1);
                ordinal += 1;
            }
        }
    }
    debug_assert_eq!(ordinal, log.tasks(problem), "log/plan task-count mismatch");
}

/// Finish the factorization from the bidiagonal `(d, e)`: run
/// [`dk_qr_factor`] with a rotation sink folding every Givens rotation
/// into `u` / `vt`, apply the sign/permutation fix-up, and return the
/// singular values (descending). On exit `A = u · diag(sv) · vt` holds
/// for whatever `A = u·B·vt` held on entry.
pub fn complete_svd(d: &[f64], e: &[f64], u: &mut Dense<f64>, vt: &mut Dense<f64>) -> Vec<f64> {
    let n = d.len();
    debug_assert_eq!((u.rows, vt.rows), (n, n));
    let mut apply = |side: GivensSide, i: usize, c: f64, s: f64| match side {
        GivensSide::Right => {
            for j in 0..n {
                let (x, y) = (vt.get(i, j), vt.get(i + 1, j));
                vt.set(i, j, c * x + s * y);
                vt.set(i + 1, j, -s * x + c * y);
            }
        }
        GivensSide::Left => {
            for r in 0..n {
                let (x, y) = (u.get(r, i), u.get(r, i + 1));
                u.set(r, i, c * x + s * y);
                u.set(r, i + 1, -s * x + c * y);
            }
        }
    };
    let factors = dk_qr_factor(d, e, Some(&mut apply));
    // Sign fix-up first (original indices), then the descending
    // permutation — the order [`DkQrFactors`] documents.
    for (i, &neg) in factors.negated.iter().enumerate() {
        if neg {
            for v in vt.row_mut(i) {
                *v = -*v;
            }
        }
    }
    let mut pu = Dense::<f64>::zeros(n, n);
    let mut pvt = Dense::<f64>::zeros(n, n);
    for (k, &src) in factors.order.iter().enumerate() {
        for r in 0..n {
            pu.set(r, k, u.get(r, src));
        }
        let (row, srow) = (pvt.row_mut(k), vt.row(src));
        // rows don't alias: pvt is a fresh matrix
        row.copy_from_slice(srow);
    }
    *u = pu;
    *vt = pvt;
    factors.sv
}

/// Full SVD of an already-banded matrix (stages 2+3 with vectors) on an
/// explicit vectors-capable [`Backend`] — the direct-call analog of
/// [`crate::pipeline::banded_singular_values_with`], and the oracle the
/// client/service vector paths are checked against. The panels are
/// bitwise identical across native backends (sequential, threadpool,
/// SIMD): the recorded reflectors are, and the replay itself is one
/// deterministic sequential pass.
pub fn banded_svd_vectors_with<T: Scalar>(
    backend: &dyn Backend,
    banded: &Banded<T>,
    bw: usize,
    params: &TuneParams,
) -> Result<SvdVectors>
where
    Banded<T>: AsBandStorageMut,
{
    let mut work = banded.clone();
    let (plan, _exec, log) = execute_reduction_logged(backend, &mut work, bw, params)?;
    let n = banded.n();
    let mut u = Dense::<f64>::identity(n);
    let mut vt = Dense::<f64>::identity(n);
    accumulate_panels(&plan, &log, 0, &mut u, &mut vt);
    let (diag, superdiag) = work.bidiagonal();
    let d: Vec<f64> = diag.iter().map(|v| v.to_f64()).collect();
    let e: Vec<f64> = superdiag.iter().map(|v| v.to_f64()).collect();
    let sv = complete_svd(&d, &e, &mut u, &mut vt);
    Ok(SvdVectors { sv, u, vt })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SequentialBackend;
    use crate::generate::random_banded;
    use crate::pipeline::jacobi::jacobi_singular_values;
    use crate::util::rng::Xoshiro256;

    fn dense_of(banded: &Banded<f64>) -> Dense<f64> {
        Dense::from_vec(banded.n(), banded.n(), banded.to_dense())
    }

    fn bidiagonal_dense(d: &[f64], e: &[f64]) -> Dense<f64> {
        let n = d.len();
        let mut b = Dense::<f64>::zeros(n, n);
        for i in 0..n {
            b.set(i, i, d[i]);
            if i + 1 < n {
                b.set(i, i + 1, e[i]);
            }
        }
        b
    }

    #[test]
    fn replayed_band_stage_reconstructs_the_input() {
        // A = U · B · Vᵀ after the chase alone — the reflector log replay
        // validated against the dense input, before any QR iteration.
        let mut rng = Xoshiro256::seed_from_u64(51);
        for (n, bw, tw) in [(40usize, 6usize, 3usize), (64, 9, 4), (96, 12, 8)] {
            let params = TuneParams { tpb: 32, tw, max_blocks: 16 };
            let banded = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
            let a0 = dense_of(&banded);
            let mut work = banded.clone();
            let (plan, _exec, log) =
                execute_reduction_logged(&SequentialBackend::new(), &mut work, bw, &params)
                    .unwrap();
            let mut u = Dense::<f64>::identity(n);
            let mut vt = Dense::<f64>::identity(n);
            accumulate_panels(&plan, &log, 0, &mut u, &mut vt);
            let (d, e) = work.bidiagonal();
            let b = bidiagonal_dense(&d, &e);
            let recon = u.matmul(&b).matmul(&vt);
            let scale = a0.fro_norm().max(1e-300);
            assert!(
                recon.max_abs_diff(&a0) <= 1e-12 * scale,
                "n={n} bw={bw}: band-stage residual {:e}",
                recon.max_abs_diff(&a0)
            );
            assert!(u.orthogonality_error() <= 1e-12, "n={n} bw={bw}: U");
            assert!(vt.orthogonality_error() <= 1e-12, "n={n} bw={bw}: Vᵀ");
        }
    }

    #[test]
    fn full_svd_reconstructs_and_matches_the_jacobi_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(52);
        for (n, bw, tw) in [(36usize, 5usize, 4usize), (48, 7, 3)] {
            let params = TuneParams { tpb: 32, tw, max_blocks: 16 };
            let banded = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
            let a0 = dense_of(&banded);
            let svd = banded_svd_vectors_with(&SequentialBackend::new(), &banded, bw, &params)
                .unwrap();
            // Descending, orthogonal, and A = U·Σ·Vᵀ.
            assert!(svd.sv.windows(2).all(|w| w[0] >= w[1]));
            assert!(svd.u.orthogonality_error() <= 1e-12);
            assert!(svd.vt.orthogonality_error() <= 1e-12);
            let mut sigma_vt = svd.vt.clone();
            for (k, &s) in svd.sv.iter().enumerate() {
                for v in sigma_vt.row_mut(k) {
                    *v *= s;
                }
            }
            let recon = svd.u.matmul(&sigma_vt);
            let scale = a0.fro_norm().max(1e-300);
            assert!(
                recon.max_abs_diff(&a0) <= 1e-11 * scale,
                "n={n} bw={bw}: residual {:e}",
                recon.max_abs_diff(&a0)
            );
            let oracle = jacobi_singular_values(&a0);
            for (got, want) in svd.sv.iter().zip(oracle.iter()) {
                assert!((got - want).abs() <= 1e-9 * want.max(1e-9), "{got} vs {want}");
            }
        }
    }

    #[test]
    fn panels_are_bitwise_identical_across_native_backends() {
        use crate::backend::{SimdBackend, ThreadpoolBackend};
        use crate::simd::SimdSpec;
        let mut rng = Xoshiro256::seed_from_u64(53);
        let (n, bw) = (64usize, 9usize);
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 12 };
        let banded = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let oracle =
            banded_svd_vectors_with(&SequentialBackend::new(), &banded, bw, &params).unwrap();
        let tp = banded_svd_vectors_with(&ThreadpoolBackend::new(3), &banded, bw, &params)
            .unwrap();
        let simd = banded_svd_vectors_with(
            &SimdBackend::with_spec(SimdSpec::scalar(), 3),
            &banded,
            bw,
            &params,
        )
        .unwrap();
        for other in [&tp, &simd] {
            assert_eq!(oracle.sv, other.sv);
            assert_eq!(oracle.u, other.u);
            assert_eq!(oracle.vt, other.vt);
        }
    }
}
