//! The three-stage singular-value pipeline (paper §I): dense → banded →
//! bidiagonal → singular values, with stage 2 running in a selectable
//! precision (the Fig. 3 protocol) and on a selectable
//! [`crate::backend::Backend`] — every stage-2 reduction here executes a
//! [`crate::plan::LaunchPlan`] through the trait, never a private loop.
//!
//! Banded-entry convenience goes through the unified [`crate::client`]
//! front door (a [`crate::client::ReductionRequest`] submitted to any
//! [`crate::client::Client`]); [`banded_singular_values_with`] remains
//! as the explicit-backend direct call the client machinery itself is
//! checked against.

use crate::backend::{
    execute_reduction, AsBandStorageMut, Backend, SequentialBackend, ThreadpoolBackend,
};
use crate::banded::dense::Dense;
use crate::banded::storage::Banded;
use crate::config::TuneParams;
use crate::error::Result;
use crate::pipeline::stage1::{dense_to_band_inplace, dense_to_band_inplace_parallel};
use crate::pipeline::stage3::{bidiagonal_singular_values, bidiagonal_singular_values_parallel};
use crate::scalar::Scalar;
use crate::util::threadpool::ThreadPool;

/// Options for a full three-stage run.
#[derive(Clone, Debug)]
pub struct SvdOptions {
    /// Intermediate bandwidth produced by stage 1.
    pub bandwidth: usize,
    /// Bulge-chasing tuning (stage 2).
    pub params: TuneParams,
}

impl Default for SvdOptions {
    fn default() -> Self {
        Self { bandwidth: 16, params: TuneParams { tpb: 32, tw: 8, max_blocks: 192 } }
    }
}

/// Timing breakdown of a pipeline run.
#[derive(Clone, Debug, Default)]
pub struct StageTimings {
    pub stage1: std::time::Duration,
    pub stage2: std::time::Duration,
    pub stage3: std::time::Duration,
}

impl StageTimings {
    pub fn total(&self) -> std::time::Duration {
        self.stage1 + self.stage2 + self.stage3
    }
}

/// Full pipeline in uniform f64 (all three stages double precision).
pub fn singular_values_3stage(a: &Dense<f64>, opts: &SvdOptions) -> (Vec<f64>, StageTimings) {
    singular_values_3stage_mixed::<f64>(a, opts)
}

/// The paper's Fig. 3 protocol: stage 1 in f64, **stage 2 in precision
/// `T`**, stage 3 in f64 — isolating the precision impact of the bulge
/// chasing under test. Stage 2 executes its launch plan on the
/// [`SequentialBackend`] (the inline reference executor).
pub fn singular_values_3stage_mixed<T: Scalar>(
    a: &Dense<f64>,
    opts: &SvdOptions,
) -> (Vec<f64>, StageTimings)
where
    Banded<T>: AsBandStorageMut,
{
    let mut times = StageTimings::default();
    let bw = opts.bandwidth.min(a.rows.saturating_sub(1)).max(1);
    let tw = opts.params.effective_tw(bw);

    // Stage 1 (f64).
    let t0 = std::time::Instant::now();
    let mut work = a.clone();
    dense_to_band_inplace(&mut work, bw);
    let band64 = Banded::<f64>::from_dense(&work.data, work.rows, bw, tw);
    times.stage1 = t0.elapsed();

    // Stage 2 in precision T, through the backend trait.
    let t0 = std::time::Instant::now();
    let mut band_t: Banded<T> = band64.convert();
    execute_reduction(&SequentialBackend::new(), &mut band_t, bw, &opts.params)
        .expect("stage-1 output is sized for the reduction");
    let (diag, superdiag) = band_t.bidiagonal();
    times.stage2 = t0.elapsed();

    // Stage 3 (f64).
    let t0 = std::time::Instant::now();
    let d: Vec<f64> = diag.iter().map(|v| v.to_f64()).collect();
    let e: Vec<f64> = superdiag.iter().map(|v| v.to_f64()).collect();
    let sv = bidiagonal_singular_values(&d, &e);
    times.stage3 = t0.elapsed();
    (sv, times)
}

/// Threaded pipeline (all stages parallel over `pool`; stage 2 executes
/// its launch plan on a [`ThreadpoolBackend`] borrowing the same pool).
pub fn singular_values_3stage_parallel(
    a: &Dense<f64>,
    opts: &SvdOptions,
    pool: &ThreadPool,
) -> (Vec<f64>, StageTimings) {
    let mut times = StageTimings::default();
    let bw = opts.bandwidth.min(a.rows.saturating_sub(1)).max(1);
    let tw = opts.params.effective_tw(bw);

    let t0 = std::time::Instant::now();
    let mut work = a.clone();
    dense_to_band_inplace_parallel(&mut work, bw, pool);
    let mut band = Banded::<f64>::from_dense(&work.data, work.rows, bw, tw);
    times.stage1 = t0.elapsed();

    let t0 = std::time::Instant::now();
    execute_reduction(&ThreadpoolBackend::borrowing(pool), &mut band, bw, &opts.params)
        .expect("stage-1 output is sized for the reduction");
    let (diag, superdiag) = band.bidiagonal();
    times.stage2 = t0.elapsed();

    let t0 = std::time::Instant::now();
    let sv = bidiagonal_singular_values_parallel(&diag, &superdiag, pool);
    times.stage3 = t0.elapsed();
    (sv, times)
}

/// Singular values of an already-banded matrix (stages 2+3 only) on an
/// explicit [`Backend`] — the pipeline's backend-selection point. The
/// reduction result is bitwise identical across native backends; a PJRT
/// backend rounds through f32. For batching, queued execution, and
/// remote serving, build a [`crate::client::ReductionRequest`] and
/// submit it through a [`crate::client::Client`] instead.
pub fn banded_singular_values_with<T: Scalar>(
    backend: &dyn Backend,
    banded: &Banded<T>,
    bw: usize,
    params: &TuneParams,
) -> Result<Vec<f64>>
where
    Banded<T>: AsBandStorageMut,
{
    let mut work = banded.clone();
    execute_reduction(backend, &mut work, bw, params)?;
    let (diag, superdiag) = work.bidiagonal();
    let d: Vec<f64> = diag.iter().map(|v| v.to_f64()).collect();
    let e: Vec<f64> = superdiag.iter().map(|v| v.to_f64()).collect();
    Ok(bidiagonal_singular_values(&d, &e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, LocalClient, ReductionRequest};
    use crate::generate::{dense_with_spectrum, random_banded, Spectrum};
    use crate::pipeline::jacobi::jacobi_singular_values;
    use crate::scalar::F16;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn pipeline_recovers_prescribed_spectrum_f64() {
        let mut rng = Xoshiro256::seed_from_u64(31);
        let n = 48;
        let sigma = Spectrum::Arithmetic.sample(n, &mut rng);
        let a = dense_with_spectrum(n, &sigma, &mut rng, n);
        let opts = SvdOptions {
            bandwidth: 6,
            params: TuneParams { tpb: 32, tw: 3, max_blocks: 192 },
        };
        let (sv, _) = singular_values_3stage(&a, &opts);
        for (got, want) in sv.iter().zip(sigma.iter()) {
            assert!((got - want).abs() < 1e-10, "{got} vs {want}");
        }
    }

    #[test]
    fn pipeline_matches_jacobi_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(32);
        let n = 32;
        let sigma = Spectrum::QuarterCircle.sample(n, &mut rng);
        let a = dense_with_spectrum(n, &sigma, &mut rng, n);
        let opts = SvdOptions {
            bandwidth: 4,
            params: TuneParams { tpb: 32, tw: 2, max_blocks: 192 },
        };
        let (sv, _) = singular_values_3stage(&a, &opts);
        let oracle = jacobi_singular_values(&a);
        for (got, want) in sv.iter().zip(oracle.iter()) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
    }

    #[test]
    fn mixed_precision_f32_has_expected_error_level() {
        let mut rng = Xoshiro256::seed_from_u64(33);
        let n = 40;
        let sigma = Spectrum::Arithmetic.sample(n, &mut rng);
        let a = dense_with_spectrum(n, &sigma, &mut rng, n);
        let opts = SvdOptions {
            bandwidth: 8,
            params: TuneParams { tpb: 32, tw: 4, max_blocks: 192 },
        };
        let (sv64, _) = singular_values_3stage_mixed::<f64>(&a, &opts);
        let (sv32, _) = singular_values_3stage_mixed::<f32>(&a, &opts);
        let (sv16, _) = singular_values_3stage_mixed::<F16>(&a, &opts);
        use crate::pipeline::stage3::relative_sv_error;
        let e64 = relative_sv_error(&sv64, &sigma);
        let e32 = relative_sv_error(&sv32, &sigma);
        let e16 = relative_sv_error(&sv16, &sigma);
        assert!(e64 < 1e-12, "fp64 error {e64}");
        assert!(e32 > e64 && e32 < 1e-4, "fp32 error {e32}");
        assert!(e16 > e32 && e16 < 0.15, "fp16 error {e16}");
    }

    #[test]
    fn parallel_pipeline_matches_sequential() {
        let pool = ThreadPool::new(4);
        let mut rng = Xoshiro256::seed_from_u64(34);
        let n = 40;
        let sigma = Spectrum::Logarithmic.sample(n, &mut rng);
        let a = dense_with_spectrum(n, &sigma, &mut rng, n);
        let opts = SvdOptions {
            bandwidth: 6,
            params: TuneParams { tpb: 32, tw: 3, max_blocks: 192 },
        };
        let (s1, _) = singular_values_3stage(&a, &opts);
        let (s2, _) = singular_values_3stage_parallel(&a, &opts, &pool);
        for (a, b) in s1.iter().zip(s2.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn banded_entry_point_matches_full_pipeline_tail() {
        let mut rng = Xoshiro256::seed_from_u64(35);
        let (n, bw) = (36, 5);
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 192 };
        let banded = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let sv =
            banded_singular_values_with(&SequentialBackend::new(), &banded, bw, &params).unwrap();
        // Oracle: densify and Jacobi.
        let dense = Dense::from_vec(n, n, banded.to_dense());
        let oracle = jacobi_singular_values(&dense);
        for (got, want) in sv.iter().zip(oracle.iter()) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
    }

    #[test]
    fn client_front_door_matches_the_direct_path_bitwise() {
        // The unified client (batched and solo) must answer exactly what
        // the direct explicit-backend path answers.
        use crate::config::{BackendKind, BatchConfig};
        let mut rng = Xoshiro256::seed_from_u64(37);
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 192 };
        let shapes = [(36usize, 5usize), (28, 4), (44, 7)];
        let mats: Vec<_> = shapes
            .iter()
            .map(|&(n, bw)| random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng))
            .collect();
        let client =
            LocalClient::direct(params, BatchConfig::default(), BackendKind::Sequential, 1)
                .unwrap();
        let mut batched = ReductionRequest::new();
        for (a, &(_, bw)) in mats.iter().zip(shapes.iter()) {
            batched = batched.problem((a.clone(), bw));
        }
        let outcome = client.submit_wait(batched).unwrap();
        for ((a, &(_, bw)), got) in mats.iter().zip(shapes.iter()).zip(outcome.problems.iter()) {
            let solo = client
                .submit_wait(ReductionRequest::new().problem((a.clone(), bw)))
                .unwrap();
            let direct =
                banded_singular_values_with(&SequentialBackend::new(), a, bw, &params).unwrap();
            assert_eq!(got.sv, solo.problems[0].sv, "bw={bw}");
            assert_eq!(solo.problems[0].sv, direct, "bw={bw}");
        }
    }

    #[test]
    fn backend_selection_point_is_bitwise_stable() {
        let mut rng = Xoshiro256::seed_from_u64(38);
        let (n, bw) = (36, 5);
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 192 };
        let banded = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let seq = banded_singular_values_with(&SequentialBackend::new(), &banded, bw, &params)
            .unwrap();
        let tp = banded_singular_values_with(&ThreadpoolBackend::new(2), &banded, bw, &params)
            .unwrap();
        assert_eq!(seq, tp);
        // The front door answers the same values.
        let client = LocalClient::new(params);
        let via_client = client
            .submit_wait(ReductionRequest::new().problem((banded.clone(), bw)))
            .unwrap();
        assert_eq!(seq, via_client.problems[0].sv);
    }

    #[test]
    fn bandwidth_tiling_choice_does_not_change_values() {
        // The paper's claim: successive band reduction (any tw) leaves
        // singular values intact.
        let mut rng = Xoshiro256::seed_from_u64(36);
        let (n, bw) = (40, 9);
        let base = random_banded::<f64>(n, bw, 8, &mut rng);
        let dense = base.to_dense();
        let mut reference: Option<Vec<f64>> = None;
        for tw in [1usize, 2, 4, 8] {
            let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
            let banded = Banded::from_dense(&dense, n, bw, params.effective_tw(bw));
            let sv = banded_singular_values_with(&SequentialBackend::new(), &banded, bw, &params)
                .unwrap();
            match &reference {
                None => reference = Some(sv),
                Some(r) => {
                    for (a, b) in sv.iter().zip(r.iter()) {
                        assert!((a - b).abs() < 1e-9, "tw={tw}: {a} vs {b}");
                    }
                }
            }
        }
    }
}
