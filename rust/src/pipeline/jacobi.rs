//! One-sided Jacobi SVD — the *independent* singular-value oracle.
//!
//! Shares no code with the three-stage pipeline (no Householder
//! reflectors, no bidiagonal form), converges to high relative accuracy,
//! and is therefore the ground truth the integration tests compare the
//! pipeline against. O(n³) per sweep; intended for n ≲ 256.

use crate::banded::dense::Dense;

/// Singular values of dense `a` (descending) by one-sided Jacobi.
pub fn jacobi_singular_values(a: &Dense<f64>) -> Vec<f64> {
    let n = a.cols;
    let m = a.rows;
    // Work on columns of a copy.
    let mut w = a.clone();
    let max_sweeps = 60;
    let tol = 1e-14;

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                // Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let x = w.get(i, p);
                    let y = w.get(i, q);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                let denom = (app * aqq).sqrt();
                if denom == 0.0 || apq.abs() <= tol * denom {
                    continue;
                }
                off = off.max(apq.abs() / denom);
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let zeta = (aqq - app) / (2.0 * apq);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let x = w.get(i, p);
                    let y = w.get(i, q);
                    w.set(i, p, c * x - s * y);
                    w.set(i, q, s * x + c * y);
                }
            }
        }
        if off <= tol {
            break;
        }
    }
    // Singular values are the column norms.
    let mut sv: Vec<f64> = (0..n)
        .map(|j| {
            (0..m)
                .map(|i| {
                    let v = w.get(i, j);
                    v * v
                })
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::{dense_with_spectrum, Spectrum};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn diagonal_matrix() {
        let mut a = Dense::<f64>::zeros(3, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, -1.0);
        a.set(2, 2, 2.0);
        let sv = jacobi_singular_values(&a);
        assert!((sv[0] - 3.0).abs() < 1e-12);
        assert!((sv[1] - 2.0).abs() < 1e-12);
        assert!((sv[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn recovers_prescribed_spectrum() {
        let mut rng = Xoshiro256::seed_from_u64(21);
        let n = 24;
        for kind in Spectrum::ALL {
            let sigma = kind.sample(n, &mut rng);
            let a = dense_with_spectrum(n, &sigma, &mut rng, n);
            let sv = jacobi_singular_values(&a);
            for (got, want) in sv.iter().zip(sigma.iter()) {
                assert!(
                    (got - want).abs() < 1e-10 * want.max(1e-8),
                    "{kind:?}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn rank_deficient_matrix_has_zero_singular_values() {
        // Two identical columns.
        let mut a = Dense::<f64>::zeros(3, 3);
        for i in 0..3 {
            a.set(i, 0, (i + 1) as f64);
            a.set(i, 1, (i + 1) as f64);
            a.set(i, 2, 1.0);
        }
        let sv = jacobi_singular_values(&a);
        assert!(sv[2].abs() < 1e-10, "{sv:?}");
    }
}
