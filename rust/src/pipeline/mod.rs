//! The three-stage singular-value pipeline.
//!
//! - [`stage1`] — dense → banded (blocked Householder, the substrate the
//!   paper takes from [11]).
//! - stage 2 — lives in [`crate::bulge`] (the paper's contribution).
//! - [`stage3`] — bidiagonal → singular values (Golub–Kahan bisection,
//!   standing in for LAPACK BDSDC).
//! - [`jacobi`] — one-sided Jacobi oracle for independent validation.
//! - [`vectors`] — singular vectors: reflector-log replay plus the
//!   Demmel–Kahan rotation stream, composing `A = U·Σ·Vᵀ`.
//! - [`svd`]    — end-to-end drivers, including the mixed-precision
//!   Fig. 3 protocol.
//!
//! Banded-entry convenience lives behind the unified [`crate::client`]
//! front door — build a [`crate::client::ReductionRequest`] and submit
//! it through a [`crate::client::Client`];
//! [`banded_singular_values_with`] remains as the one-shot
//! explicit-backend call the client machinery is checked against.

pub mod dk_qr;
pub mod jacobi;
pub mod stage1;
pub mod stage3;
pub mod svd;
pub mod vectors;

pub use dk_qr::{dk_qr_factor, dk_qr_singular_values, DkQrFactors, GivensSide};
pub use jacobi::jacobi_singular_values;
pub use stage1::{dense_to_band, dense_to_band_inplace, dense_to_band_inplace_parallel};
pub use stage3::{
    bidiagonal_singular_values, bidiagonal_singular_values_parallel, relative_sv_error,
};
pub use svd::{
    banded_singular_values_with, singular_values_3stage, singular_values_3stage_mixed,
    singular_values_3stage_parallel, StageTimings, SvdOptions,
};
pub use vectors::{accumulate_panels, banded_svd_vectors_with, complete_svd, SvdVectors};
