//! Demmel–Kahan implicit zero-shift QR for bidiagonal singular values —
//! the second stage-3 solver (LAPACK `bdsqr`-family), cross-checking the
//! Golub–Kahan bisection in `stage3.rs`.
//!
//! The zero-shift variant (Demmel & Kahan, "Accurate singular values of
//! bidiagonal matrices", 1990) computes every singular value to high
//! relative accuracy using only Givens rotations whose rotation data
//! never mixes magnitudes. A Wilkinson-style shift is used once the
//! iteration is far from the deflation threshold, for cubic convergence;
//! near convergence we switch to zero-shift to protect tiny values.

/// Tolerance factor (LAPACK uses ~ machine-eps · max-dim heuristics).
const TOL: f64 = 100.0 * f64::EPSILON;
const MAX_SWEEPS_PER_VALUE: usize = 40;

/// Which factor a Givens rotation emitted by [`dk_qr_factor`] updates.
///
/// The iteration computes `B = U_B · Σ' · V_Bᵀ` as a product of plane
/// rotations: a `Right` rotation acts on the row space (rotate rows
/// `i, i+1` of `Vᵀ`), a `Left` rotation on the column space (rotate
/// columns `i, i+1` of `U`). Both use the same convention:
/// `x' = c·x + s·y`, `y' = −s·x + c·y`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum GivensSide {
    /// Update the left factor: rotate columns `i, i+1` of `U`.
    Left,
    /// Update the right factor: rotate rows `i, i+1` of `Vᵀ`.
    Right,
}

/// What [`dk_qr_factor`] returns besides the rotation stream: the sorted
/// singular values plus the sign/permutation fix-up that maps the raw
/// iterated diagonal onto them. Apply in this order: first flip row `i`
/// of `Vᵀ` wherever `negated[i]`, then permute (`U[:,k] ← U[:,order[k]]`,
/// `Vᵀ[k,:] ← Vᵀ[order[k],:]`); then `sv[k] = |d[order[k]]|` descending.
#[derive(Clone, Debug)]
pub struct DkQrFactors {
    /// Singular values, descending.
    pub sv: Vec<f64>,
    /// `order[k]` = original index of the k-th largest singular value
    /// (stable under ties).
    pub order: Vec<usize>,
    /// `negated[i]`: the iterated diagonal converged to a negative value
    /// at original index `i`, so row `i` of `Vᵀ` must be sign-flipped.
    pub negated: Vec<bool>,
}

/// The optional rotation sink: called once per Givens rotation, in
/// application order, with `(side, i, c, s)`.
type Sink<'a> = Option<&'a mut dyn FnMut(GivensSide, usize, f64, f64)>;

#[inline]
fn emit(sink: &mut Sink, side: GivensSide, i: usize, c: f64, s: f64) {
    if let Some(f) = sink.as_mut() {
        f(side, i, c, s);
    }
}

/// Givens rotation (c, s, r) with c·a + s·b = r, −s·a + c·b = 0
/// (LAPACK `lartg`-style, guarded for zeros).
#[inline]
fn rotg(a: f64, b: f64) -> (f64, f64, f64) {
    if b == 0.0 {
        (1.0, 0.0, a)
    } else if a == 0.0 {
        (0.0, 1.0, b)
    } else {
        let r = a.hypot(b);
        (a / r, b / r, r)
    }
}

/// One zero-shift QR sweep on d[lo..=hi], e[lo..hi] (Demmel–Kahan
/// "implicit zero-shift" recurrence).
fn zero_shift_sweep(d: &mut [f64], e: &mut [f64], lo: usize, hi: usize, sink: &mut Sink) {
    let (mut c_old, mut s_old) = (1.0f64, 0.0f64);
    let mut c = 1.0f64;
    for i in lo..hi {
        let (c_new, s_new, r) = rotg(d[i] * c, e[i]);
        emit(sink, GivensSide::Right, i, c_new, s_new);
        if i > lo {
            e[i - 1] = s_old * r;
        }
        let (co, so, ro) = rotg(c_old * r, d[i + 1] * s_new);
        emit(sink, GivensSide::Left, i, co, so);
        d[i] = ro;
        c = c_new;
        c_old = co;
        s_old = so;
    }
    let h = d[hi] * c;
    e[hi - 1] = h * s_old;
    d[hi] = h * c_old;
}

/// One shifted QR sweep (standard bulge-chase with shift σ²).
fn shifted_sweep(d: &mut [f64], e: &mut [f64], lo: usize, hi: usize, shift: f64, sink: &mut Sink) {
    let mut f = (d[lo].abs() - shift) * (1.0f64.copysign(d[lo]) + shift / d[lo]);
    let mut g = e[lo];
    for i in lo..hi {
        let (c, s, r) = rotg(f, g);
        emit(sink, GivensSide::Right, i, c, s);
        if i > lo {
            e[i - 1] = r;
        }
        f = c * d[i] + s * e[i];
        e[i] = c * e[i] - s * d[i];
        g = s * d[i + 1];
        d[i + 1] *= c;
        let (c2, s2, r2) = rotg(f, g);
        emit(sink, GivensSide::Left, i, c2, s2);
        d[i] = r2;
        f = c2 * e[i] + s2 * d[i + 1];
        d[i + 1] = c2 * d[i + 1] - s2 * e[i];
        if i < hi - 1 {
            g = s2 * e[i + 1];
            e[i + 1] *= c2;
        }
    }
    e[hi - 1] = f;
}

/// Wilkinson-style shift from the trailing 2×2 of BᵀB.
fn trailing_shift(d: &[f64], e: &[f64], hi: usize) -> f64 {
    let dn = d[hi];
    let dn1 = d[hi - 1];
    let en1 = e[hi - 1];
    let en2 = if hi >= 2 { e[hi - 2] } else { 0.0 };
    // Eigenvalue of [[dn1²+en2², dn1·en1], [dn1·en1, dn²+en1²]] closest
    // to the trailing entry.
    let a = dn1 * dn1 + en2 * en2;
    let b = dn1 * en1;
    let c = dn * dn + en1 * en1;
    let tr = 0.5 * (a + c);
    let det = a * c - b * b;
    let disc = (tr * tr - det).max(0.0).sqrt();
    let l1 = tr + disc;
    let l2 = tr - disc;
    let lam = if (l1 - c).abs() < (l2 - c).abs() { l1 } else { l2 };
    lam.max(0.0).sqrt()
}

/// All singular values of the upper bidiagonal (d, e), descending, by
/// Demmel–Kahan QR iteration. O(n²) typical.
pub fn dk_qr_singular_values(d_in: &[f64], e_in: &[f64]) -> Vec<f64> {
    dk_qr_factor(d_in, e_in, None).sv
}

/// Demmel–Kahan QR iteration with the rotation order exposed: every
/// Givens rotation the sweeps apply is reported to `sink` (when given),
/// in application order, so callers can accumulate the `U`/`Vᵀ` factors
/// alongside the values. With `sink = None` this is exactly
/// [`dk_qr_singular_values`] — the iteration takes the same branches and
/// produces the same bits; the sink never influences the numerics.
///
/// Deflation (zeroing a negligible off-diagonal) emits no rotation — it
/// is an `O(ε)` backward perturbation of `B`, inside the residual bound
/// the factorization already carries.
pub fn dk_qr_factor(d_in: &[f64], e_in: &[f64], mut sink: Sink) -> DkQrFactors {
    let n = d_in.len();
    if n == 0 {
        return DkQrFactors { sv: Vec::new(), order: Vec::new(), negated: Vec::new() };
    }
    assert_eq!(e_in.len() + 1, n);
    let mut d = d_in.to_vec();
    let mut e = e_in.to_vec();
    let scale = d
        .iter()
        .chain(e.iter())
        .fold(0.0f64, |m, &x| m.max(x.abs()));
    if scale == 0.0 {
        return DkQrFactors {
            sv: vec![0.0; n],
            order: (0..n).collect(),
            negated: vec![false; n],
        };
    }

    let mut hi = n - 1;
    let mut budget = MAX_SWEEPS_PER_VALUE * n;
    while hi > 0 && budget > 0 {
        // Deflate negligible off-diagonals.
        let mut deflated = false;
        for i in (0..hi).rev() {
            if e[i].abs() <= TOL * (d[i].abs() + d[i + 1].abs()).max(scale * f64::EPSILON) {
                e[i] = 0.0;
                if i == hi - 1 {
                    hi -= 1;
                    deflated = true;
                    break;
                }
            }
        }
        if deflated {
            continue;
        }
        if hi == 0 {
            break;
        }
        // Active block [lo, hi]: walk up to the nearest split.
        let mut lo = hi;
        while lo > 0 && e[lo - 1] != 0.0 {
            lo -= 1;
        }
        if lo == hi {
            hi -= 1;
            continue;
        }
        // Choose shift: zero-shift when the block is nearly converged or
        // badly graded (protects relative accuracy of tiny values).
        let dmin = d[lo..=hi].iter().fold(f64::INFINITY, |m, &x| m.min(x.abs()));
        let emax = e[lo..hi].iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        let shift = trailing_shift(&d, &e, hi);
        if shift <= TOL.sqrt() * dmin || emax <= TOL.sqrt() * dmin || d[lo] == 0.0 {
            zero_shift_sweep(&mut d, &mut e, lo, hi, &mut sink);
        } else {
            shifted_sweep(&mut d, &mut e, lo, hi, shift, &mut sink);
        }
        budget -= 1;
    }
    let negated: Vec<bool> = d.iter().map(|&x| x < 0.0).collect();
    let mut order: Vec<usize> = (0..n).collect();
    // Stable descending sort by magnitude: ties keep original index
    // order, matching the value sort of `dk_qr_singular_values` bit for
    // bit (equal magnitudes are identical bits after `abs`).
    order.sort_by(|&a, &b| d[b].abs().partial_cmp(&d[a].abs()).unwrap());
    let sv: Vec<f64> = order.iter().map(|&i| d[i].abs()).collect();
    DkQrFactors { sv, order, negated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_bidiagonal;
    use crate::pipeline::stage3::bidiagonal_singular_values;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn matches_bisection_on_random_bidiagonals() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for n in [2usize, 3, 5, 16, 40, 100] {
            let (d, e) = random_bidiagonal(n, &mut rng);
            let qr = dk_qr_singular_values(&d, &e);
            let bis = bidiagonal_singular_values(&d, &e);
            for (a, b) in qr.iter().zip(bis.iter()) {
                assert!(
                    (a - b).abs() <= 1e-10 * b.max(1e-10),
                    "n={n}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn diagonal_input() {
        let sv = dk_qr_singular_values(&[3.0, -1.0, 2.0], &[0.0, 0.0]);
        assert!((sv[0] - 3.0).abs() < 1e-14);
        assert!((sv[1] - 2.0).abs() < 1e-14);
        assert!((sv[2] - 1.0).abs() < 1e-14);
    }

    #[test]
    fn graded_matrix_small_values_relatively_accurate() {
        // The Demmel–Kahan selling point: tiny σ to high relative accuracy.
        let d = vec![1.0, 1e-4, 1e-8];
        let e = vec![1e-2, 1e-6];
        let qr = dk_qr_singular_values(&d, &e);
        let bis = bidiagonal_singular_values(&d, &e);
        for (a, b) in qr.iter().zip(bis.iter()) {
            assert!((a - b).abs() <= 1e-8 * b, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_matrix_and_empty() {
        assert_eq!(dk_qr_singular_values(&[0.0, 0.0], &[0.0]), vec![0.0, 0.0]);
        assert!(dk_qr_singular_values(&[], &[]).is_empty());
    }

    #[test]
    fn frobenius_identity() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (d, e) = random_bidiagonal(64, &mut rng);
        let sv = dk_qr_singular_values(&d, &e);
        let ssq: f64 = sv.iter().map(|s| s * s).sum();
        let fro: f64 =
            d.iter().map(|x| x * x).sum::<f64>() + e.iter().map(|x| x * x).sum::<f64>();
        assert!((ssq - fro).abs() < 1e-8 * fro, "{ssq} vs {fro}");
    }

    #[test]
    fn factor_without_sink_is_bitwise_the_value_solver() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        for n in [1usize, 2, 7, 33, 80] {
            let (d, e) = random_bidiagonal(n, &mut rng);
            let factors = dk_qr_factor(&d, &e, None);
            let sv = dk_qr_singular_values(&d, &e);
            assert_eq!(factors.sv.len(), n);
            assert_eq!(factors.order.len(), n);
            assert_eq!(factors.negated.len(), n);
            for (a, b) in factors.sv.iter().zip(sv.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
            }
            // order is a permutation.
            let mut seen = vec![false; n];
            for &i in &factors.order {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
    }

    #[test]
    fn sink_presence_never_changes_the_values() {
        // The sink is an observer: the iteration's branches and bits are
        // identical with or without one attached.
        let mut rng = Xoshiro256::seed_from_u64(7);
        let (d, e) = random_bidiagonal(48, &mut rng);
        let silent = dk_qr_factor(&d, &e, None);
        let mut rotations = 0usize;
        let mut count = |_: GivensSide, _: usize, _: f64, _: f64| rotations += 1;
        let watched = dk_qr_factor(&d, &e, Some(&mut count));
        for (a, b) in silent.sv.iter().zip(watched.sv.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(silent.order, watched.order);
        assert_eq!(silent.negated, watched.negated);
        assert!(rotations > 0, "a 48×48 iteration must rotate");
    }

    /// Replay the rotation stream into dense U/Vᵀ and check the full
    /// factorization — Givens accumulation verified independently of the
    /// band-reduction stages.
    #[test]
    fn rotation_stream_reconstructs_the_bidiagonal() {
        use crate::banded::dense::Dense;

        let mut rng = Xoshiro256::seed_from_u64(8);
        for n in [2usize, 5, 24, 60] {
            let (d, e) = random_bidiagonal(n, &mut rng);
            let mut u = Dense::<f64>::identity(n);
            let mut vt = Dense::<f64>::identity(n);
            let mut apply = |side: GivensSide, i: usize, c: f64, s: f64| match side {
                GivensSide::Right => {
                    for j in 0..n {
                        let (x, y) = (vt.get(i, j), vt.get(i + 1, j));
                        vt.set(i, j, c * x + s * y);
                        vt.set(i + 1, j, -s * x + c * y);
                    }
                }
                GivensSide::Left => {
                    for r in 0..n {
                        let (x, y) = (u.get(r, i), u.get(r, i + 1));
                        u.set(r, i, c * x + s * y);
                        u.set(r, i + 1, -s * x + c * y);
                    }
                }
            };
            let factors = dk_qr_factor(&d, &e, Some(&mut apply));
            // Sign fix-up, then the descending-magnitude permutation.
            for (i, &neg) in factors.negated.iter().enumerate() {
                if neg {
                    for v in vt.row_mut(i) {
                        *v = -*v;
                    }
                }
            }
            let mut pu = Dense::<f64>::zeros(n, n);
            let mut pvt = Dense::<f64>::zeros(n, n);
            for (k, &src) in factors.order.iter().enumerate() {
                for r in 0..n {
                    pu.set(r, k, u.get(r, src));
                }
                for j in 0..n {
                    pvt.set(k, j, vt.get(src, j));
                }
            }
            // U · Σ · Vᵀ must reproduce B.
            let mut sigma_vt = pvt.clone();
            for (k, &s) in factors.sv.iter().enumerate() {
                for v in sigma_vt.row_mut(k) {
                    *v *= s;
                }
            }
            let recon = pu.matmul(&sigma_vt);
            let mut b = Dense::<f64>::zeros(n, n);
            for i in 0..n {
                b.set(i, i, d[i]);
                if i + 1 < n {
                    b.set(i, i + 1, e[i]);
                }
            }
            let scale = b.fro_norm().max(1e-300);
            assert!(
                recon.max_abs_diff(&b) <= 1e-12 * scale,
                "n={n}: reconstruction error {:e}",
                recon.max_abs_diff(&b)
            );
            assert!(pu.orthogonality_error() <= 1e-12, "n={n}: U not orthogonal");
            assert!(pvt.orthogonality_error() <= 1e-12, "n={n}: V not orthogonal");
        }
    }
}
