//! Demmel–Kahan implicit zero-shift QR for bidiagonal singular values —
//! the second stage-3 solver (LAPACK `bdsqr`-family), cross-checking the
//! Golub–Kahan bisection in `stage3.rs`.
//!
//! The zero-shift variant (Demmel & Kahan, "Accurate singular values of
//! bidiagonal matrices", 1990) computes every singular value to high
//! relative accuracy using only Givens rotations whose rotation data
//! never mixes magnitudes. A Wilkinson-style shift is used once the
//! iteration is far from the deflation threshold, for cubic convergence;
//! near convergence we switch to zero-shift to protect tiny values.

/// Tolerance factor (LAPACK uses ~ machine-eps · max-dim heuristics).
const TOL: f64 = 100.0 * f64::EPSILON;
const MAX_SWEEPS_PER_VALUE: usize = 40;

/// Givens rotation (c, s, r) with c·a + s·b = r, −s·a + c·b = 0
/// (LAPACK `lartg`-style, guarded for zeros).
#[inline]
fn rotg(a: f64, b: f64) -> (f64, f64, f64) {
    if b == 0.0 {
        (1.0, 0.0, a)
    } else if a == 0.0 {
        (0.0, 1.0, b)
    } else {
        let r = a.hypot(b);
        (a / r, b / r, r)
    }
}

/// One zero-shift QR sweep on d[lo..=hi], e[lo..hi] (Demmel–Kahan
/// "implicit zero-shift" recurrence).
fn zero_shift_sweep(d: &mut [f64], e: &mut [f64], lo: usize, hi: usize) {
    let (mut c_old, mut s_old) = (1.0f64, 0.0f64);
    let mut c = 1.0f64;
    for i in lo..hi {
        let (c_new, s_new, r) = rotg(d[i] * c, e[i]);
        if i > lo {
            e[i - 1] = s_old * r;
        }
        let (co, so, ro) = rotg(c_old * r, d[i + 1] * s_new);
        d[i] = ro;
        c = c_new;
        c_old = co;
        s_old = so;
    }
    let h = d[hi] * c;
    e[hi - 1] = h * s_old;
    d[hi] = h * c_old;
}

/// One shifted QR sweep (standard bulge-chase with shift σ²).
fn shifted_sweep(d: &mut [f64], e: &mut [f64], lo: usize, hi: usize, shift: f64) {
    let mut f = (d[lo].abs() - shift) * (1.0f64.copysign(d[lo]) + shift / d[lo]);
    let mut g = e[lo];
    for i in lo..hi {
        let (c, s, r) = rotg(f, g);
        if i > lo {
            e[i - 1] = r;
        }
        f = c * d[i] + s * e[i];
        e[i] = c * e[i] - s * d[i];
        g = s * d[i + 1];
        d[i + 1] *= c;
        let (c2, s2, r2) = rotg(f, g);
        d[i] = r2;
        f = c2 * e[i] + s2 * d[i + 1];
        d[i + 1] = c2 * d[i + 1] - s2 * e[i];
        if i < hi - 1 {
            g = s2 * e[i + 1];
            e[i + 1] *= c2;
        }
    }
    e[hi - 1] = f;
}

/// Wilkinson-style shift from the trailing 2×2 of BᵀB.
fn trailing_shift(d: &[f64], e: &[f64], hi: usize) -> f64 {
    let dn = d[hi];
    let dn1 = d[hi - 1];
    let en1 = e[hi - 1];
    let en2 = if hi >= 2 { e[hi - 2] } else { 0.0 };
    // Eigenvalue of [[dn1²+en2², dn1·en1], [dn1·en1, dn²+en1²]] closest
    // to the trailing entry.
    let a = dn1 * dn1 + en2 * en2;
    let b = dn1 * en1;
    let c = dn * dn + en1 * en1;
    let tr = 0.5 * (a + c);
    let det = a * c - b * b;
    let disc = (tr * tr - det).max(0.0).sqrt();
    let l1 = tr + disc;
    let l2 = tr - disc;
    let lam = if (l1 - c).abs() < (l2 - c).abs() { l1 } else { l2 };
    lam.max(0.0).sqrt()
}

/// All singular values of the upper bidiagonal (d, e), descending, by
/// Demmel–Kahan QR iteration. O(n²) typical.
pub fn dk_qr_singular_values(d_in: &[f64], e_in: &[f64]) -> Vec<f64> {
    let n = d_in.len();
    if n == 0 {
        return Vec::new();
    }
    assert_eq!(e_in.len() + 1, n);
    let mut d = d_in.to_vec();
    let mut e = e_in.to_vec();
    let scale = d
        .iter()
        .chain(e.iter())
        .fold(0.0f64, |m, &x| m.max(x.abs()));
    if scale == 0.0 {
        return vec![0.0; n];
    }

    let mut hi = n - 1;
    let mut budget = MAX_SWEEPS_PER_VALUE * n;
    while hi > 0 && budget > 0 {
        // Deflate negligible off-diagonals.
        let mut deflated = false;
        for i in (0..hi).rev() {
            if e[i].abs() <= TOL * (d[i].abs() + d[i + 1].abs()).max(scale * f64::EPSILON) {
                e[i] = 0.0;
                if i == hi - 1 {
                    hi -= 1;
                    deflated = true;
                    break;
                }
            }
        }
        if deflated {
            continue;
        }
        if hi == 0 {
            break;
        }
        // Active block [lo, hi]: walk up to the nearest split.
        let mut lo = hi;
        while lo > 0 && e[lo - 1] != 0.0 {
            lo -= 1;
        }
        if lo == hi {
            hi -= 1;
            continue;
        }
        // Choose shift: zero-shift when the block is nearly converged or
        // badly graded (protects relative accuracy of tiny values).
        let dmin = d[lo..=hi].iter().fold(f64::INFINITY, |m, &x| m.min(x.abs()));
        let emax = e[lo..hi].iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        let shift = trailing_shift(&d, &e, hi);
        if shift <= TOL.sqrt() * dmin || emax <= TOL.sqrt() * dmin || d[lo] == 0.0 {
            zero_shift_sweep(&mut d, &mut e, lo, hi);
        } else {
            shifted_sweep(&mut d, &mut e, lo, hi, shift);
        }
        budget -= 1;
    }
    let mut sv: Vec<f64> = d.iter().map(|x| x.abs()).collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_bidiagonal;
    use crate::pipeline::stage3::bidiagonal_singular_values;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn matches_bisection_on_random_bidiagonals() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for n in [2usize, 3, 5, 16, 40, 100] {
            let (d, e) = random_bidiagonal(n, &mut rng);
            let qr = dk_qr_singular_values(&d, &e);
            let bis = bidiagonal_singular_values(&d, &e);
            for (a, b) in qr.iter().zip(bis.iter()) {
                assert!(
                    (a - b).abs() <= 1e-10 * b.max(1e-10),
                    "n={n}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn diagonal_input() {
        let sv = dk_qr_singular_values(&[3.0, -1.0, 2.0], &[0.0, 0.0]);
        assert!((sv[0] - 3.0).abs() < 1e-14);
        assert!((sv[1] - 2.0).abs() < 1e-14);
        assert!((sv[2] - 1.0).abs() < 1e-14);
    }

    #[test]
    fn graded_matrix_small_values_relatively_accurate() {
        // The Demmel–Kahan selling point: tiny σ to high relative accuracy.
        let d = vec![1.0, 1e-4, 1e-8];
        let e = vec![1e-2, 1e-6];
        let qr = dk_qr_singular_values(&d, &e);
        let bis = bidiagonal_singular_values(&d, &e);
        for (a, b) in qr.iter().zip(bis.iter()) {
            assert!((a - b).abs() <= 1e-8 * b, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_matrix_and_empty() {
        assert_eq!(dk_qr_singular_values(&[0.0, 0.0], &[0.0]), vec![0.0, 0.0]);
        assert!(dk_qr_singular_values(&[], &[]).is_empty());
    }

    #[test]
    fn frobenius_identity() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (d, e) = random_bidiagonal(64, &mut rng);
        let sv = dk_qr_singular_values(&d, &e);
        let ssq: f64 = sv.iter().map(|s| s * s).sum();
        let fro: f64 =
            d.iter().map(|x| x * x).sum::<f64>() + e.iter().map(|x| x * x).sum::<f64>();
        assert!((ssq - fro).abs() < 1e-8 * fro, "{ssq} vs {fro}");
    }
}
