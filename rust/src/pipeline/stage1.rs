//! Stage 1: dense → upper-banded reduction ("ge2gb").
//!
//! Classical two-sided Householder band reduction: at step k, a left
//! reflector annihilates column k below the diagonal, then a right
//! reflector annihilates row k beyond column k+bw. After n steps the
//! matrix is upper-banded with bandwidth `bw` and the same singular
//! values. This is the substrate the paper assumes from prior work [11];
//! the Fig. 3 protocol runs it in FP64.

use crate::banded::dense::Dense;
use crate::banded::storage::Banded;
use crate::householder::{apply_reflector_cols, apply_reflector_rows, make_reflector};
use crate::scalar::Scalar;
use crate::util::threadpool::ThreadPool;

/// Reduce dense `a` (n×n, row-major) to upper-banded form with bandwidth
/// `bw`, in place. Returns nothing; the band can be extracted with
/// [`Banded::from_dense`].
pub fn dense_to_band_inplace<T: Scalar>(a: &mut Dense<T>, bw: usize) {
    assert_eq!(a.rows, a.cols, "square matrices only");
    assert!(bw >= 1, "bandwidth must be ≥ 1");
    let n = a.rows;
    let mut v = Vec::new();
    for k in 0..n {
        // Left reflector: annihilate A[k+1.., k].
        if k + 1 < n {
            let m = n - k;
            v.clear();
            v.extend((0..m).map(|i| a.get(k + i, k)));
            let tau = make_reflector(&mut v);
            if tau != T::zero() {
                let tail = v[1..].to_vec();
                apply_reflector_rows(a, tau, &tail, k, k, n - 1);
                // Exact zeros below the diagonal.
                a.set(k, k, v[0]);
                for i in (k + 1)..n {
                    a.set(i, k, T::zero());
                }
            }
        }
        // Right reflector: annihilate A[k, k+bw+1..].
        if k + bw + 1 < n {
            let c0 = k + bw;
            let m = n - c0;
            v.clear();
            v.extend((0..m).map(|j| a.get(k, c0 + j)));
            let tau = make_reflector(&mut v);
            if tau != T::zero() {
                let tail = v[1..].to_vec();
                apply_reflector_cols(a, tau, &tail, c0, k, n - 1);
                a.set(k, c0, v[0]);
                for j in (c0 + 1)..n {
                    a.set(k, j, T::zero());
                }
            }
        }
    }
}

/// Threaded variant: the reflector applications (the O(n²) inner work per
/// step) are split over the pool by column/row blocks.
pub fn dense_to_band_inplace_parallel<T: Scalar>(a: &mut Dense<T>, bw: usize, pool: &ThreadPool) {
    assert_eq!(a.rows, a.cols, "square matrices only");
    assert!(bw >= 1);
    let n = a.rows;
    let mut v: Vec<T> = Vec::new();
    let shared = SharedDense(a as *mut Dense<T>);
    let shared = &shared;

    for k in 0..n {
        if k + 1 < n {
            let m = n - k;
            v.clear();
            v.extend((0..m).map(|i| a.get(k + i, k)));
            let tau = make_reflector(&mut v);
            if tau != T::zero() {
                let tail = &v[1..];
                let n_chunks = pool.len().max(1) * 2;
                pool.for_each_chunk(n - k, n_chunks, |range| {
                    // SAFETY: chunks partition the column range; a left
                    // reflector application touches disjoint columns.
                    let a = unsafe { &mut *shared.get() };
                    apply_reflector_rows(a, tau, tail, k, k + range.start, k + range.end - 1);
                });
                let a = unsafe { &mut *shared.get() };
                a.set(k, k, v[0]);
                for i in (k + 1)..n {
                    a.set(i, k, T::zero());
                }
            }
        }
        if k + bw + 1 < n {
            let c0 = k + bw;
            let m = n - c0;
            v.clear();
            v.extend((0..m).map(|j| a.get(k, c0 + j)));
            let tau = make_reflector(&mut v);
            if tau != T::zero() {
                let tail = &v[1..];
                let n_chunks = pool.len().max(1) * 2;
                pool.for_each_chunk(n - k, n_chunks, |range| {
                    // SAFETY: chunks partition the row range; a right
                    // reflector application touches disjoint rows.
                    let a = unsafe { &mut *shared.get() };
                    apply_reflector_cols(a, tau, tail, c0, k + range.start, k + range.end - 1);
                });
                let a = unsafe { &mut *shared.get() };
                a.set(k, c0, v[0]);
                for j in (c0 + 1)..n {
                    a.set(k, j, T::zero());
                }
            }
        }
    }
}

struct SharedDense<T>(*mut Dense<T>);
unsafe impl<T: Send> Send for SharedDense<T> {}
unsafe impl<T: Send> Sync for SharedDense<T> {}

impl<T> SharedDense<T> {
    fn get(&self) -> *mut Dense<T> {
        self.0
    }
}

/// Convenience: reduce dense → banded storage ready for bulge chasing
/// with inner tilewidth `tw`.
pub fn dense_to_band<T: Scalar>(a: &Dense<T>, bw: usize, tw: usize) -> Banded<T> {
    let mut work = a.clone();
    dense_to_band_inplace(&mut work, bw);
    Banded::from_dense(&work.data, work.rows, bw, tw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::{dense_with_spectrum, Spectrum};
    use crate::util::rng::Xoshiro256;

    fn random_dense(n: usize, seed: u64) -> Dense<f64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let sigma = Spectrum::Arithmetic.sample(n, &mut rng);
        dense_with_spectrum(n, &sigma, &mut rng, n)
    }

    #[test]
    fn produces_upper_banded_form() {
        let n = 24;
        for bw in [1usize, 2, 4, 8] {
            let mut a = random_dense(n, bw as u64);
            dense_to_band_inplace(&mut a, bw);
            for i in 0..n {
                for j in 0..n {
                    let inside = j >= i && j - i <= bw;
                    if !inside {
                        assert!(
                            a.get(i, j).abs() < 1e-12,
                            "bw={bw}: ({i},{j}) = {}",
                            a.get(i, j)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn preserves_frobenius_norm() {
        let n = 32;
        let mut a = random_dense(n, 9);
        let before = a.fro_norm();
        dense_to_band_inplace(&mut a, 4);
        assert!((a.fro_norm() - before).abs() < 1e-10 * before);
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let pool = ThreadPool::new(4);
        let n = 28;
        for bw in [2usize, 5] {
            let mut a1 = random_dense(n, 100 + bw as u64);
            let mut a2 = a1.clone();
            dense_to_band_inplace(&mut a1, bw);
            dense_to_band_inplace_parallel(&mut a2, bw, &pool);
            assert_eq!(a1.data, a2.data, "bw={bw}");
        }
    }

    #[test]
    fn band_extraction_roundtrip() {
        let n = 20;
        let a = random_dense(n, 11);
        let banded = dense_to_band(&a, 3, 2);
        assert_eq!(banded.max_off_band(3), 0.0);
        assert!((banded.fro_norm() - a.fro_norm()).abs() < 1e-10 * a.fro_norm());
    }

    #[test]
    fn bandwidth_one_gives_bidiagonal_directly() {
        // bw = 1 makes stage 1 a full Golub–Kahan bidiagonalization.
        let n = 16;
        let mut a = random_dense(n, 12);
        dense_to_band_inplace(&mut a, 1);
        for i in 0..n {
            for j in 0..n {
                if j != i && j != i + 1 {
                    assert!(a.get(i, j).abs() < 1e-12, "({i},{j})");
                }
            }
        }
    }
}
