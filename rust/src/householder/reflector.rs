//! Reflector construction and application.

use crate::banded::dense::Dense;
use crate::scalar::Scalar;
use crate::simd::SimdSpec;

/// Compute a Householder reflector for `x` (length ≥ 1), LAPACK
/// `larfg`-style, **in place**:
///
/// on exit `x[0] = β` (the new leading value) and `x[1..] = v[1..]` (the
/// reflector tail; `v[0] = 1` is implicit). Returns `τ`.
///
/// `τ = 0` (identity) when the tail is exactly zero — the "near-zero
/// element" guard that keeps bulge chasing stable when a bulge is already
/// annihilated.
pub fn make_reflector<T: Scalar>(x: &mut [T]) -> T {
    make_reflector_simd(x, SimdSpec::scalar())
}

/// [`make_reflector`] with the column-norm reduction routed through the
/// [`Scalar::simd_tail_sum_squares`] hook under `spec`. With a
/// non-contracting spec the reduction stays sequential, so this is
/// bitwise-identical to [`make_reflector`]; a contracting spec trades
/// that for the ulp-bounded deterministic reduction (see
/// [`crate::simd`]).
pub fn make_reflector_simd<T: Scalar>(x: &mut [T], spec: SimdSpec) -> T {
    let m = x.len();
    if m <= 1 {
        return T::zero();
    }
    // ||x[1..]||² with scaling guard: compute in f64 for the norm only —
    // the working precision still dominates rounding via the stored v, β.
    let ssq = T::simd_tail_sum_squares(spec, &x[1..]);
    make_reflector_with_sumsq(x, ssq)
}

/// The tail of reflector construction, once `ssq = Σ to_f64(x[i])²` over
/// `x[1..]` is known. Split out so every norm strategy (sequential,
/// contracted lanes) funnels into one β/τ/scale computation.
fn make_reflector_with_sumsq<T: Scalar>(x: &mut [T], ssq: f64) -> T {
    if ssq == 0.0 {
        return T::zero();
    }
    let a = x[0].to_f64();
    let norm = (a * a + ssq).sqrt();
    // β takes the opposite sign of α to avoid cancellation.
    let beta = if a >= 0.0 { -norm } else { norm };
    let tau = (beta - a) / beta;
    let scale = 1.0 / (a - beta);
    for v in &mut x[1..] {
        *v = T::from_f64(v.to_f64() * scale);
    }
    x[0] = T::from_f64(beta);
    T::from_f64(tau)
}

/// Apply `H = I − τ v vᵀ` to a vector `y` (same length as v, `v[0] = 1`
/// implicit, `v_tail = v[1..]`): `y ← y − τ (vᵀ y) v`.
#[inline]
pub fn apply_reflector_vec<T: Scalar>(tau: T, v_tail: &[T], y: &mut [T]) {
    debug_assert_eq!(v_tail.len() + 1, y.len());
    if tau == T::zero() {
        return;
    }
    let mut dot = y[0];
    for (vi, yi) in v_tail.iter().zip(y[1..].iter()) {
        dot = vi.mul_add(*yi, dot);
    }
    let c = tau * dot;
    y[0] = y[0] - c;
    for (vi, yi) in v_tail.iter().zip(y[1..].iter_mut()) {
        *yi = *yi - c * *vi;
    }
}

/// Apply `H` from the **left** to rows `r0..r0+len(v)` of dense `a`,
/// columns `j0..j1` (inclusive): A ← H A.
pub fn apply_reflector_rows<T: Scalar>(
    a: &mut Dense<T>,
    tau: T,
    v_tail: &[T],
    r0: usize,
    j0: usize,
    j1: usize,
) {
    if tau == T::zero() {
        return;
    }
    let m = v_tail.len() + 1;
    for j in j0..=j1 {
        // dot = vᵀ A[r0.., j]
        let mut dot = a.get(r0, j);
        for (k, vi) in v_tail.iter().enumerate() {
            dot = vi.mul_add(a.get(r0 + 1 + k, j), dot);
        }
        let c = tau * dot;
        for i in 0..m {
            let vi = if i == 0 { T::one() } else { v_tail[i - 1] };
            let cur = a.get(r0 + i, j);
            a.set(r0 + i, j, cur - c * vi);
        }
    }
}

/// Apply `H` from the **right** to columns `c0..c0+len(v)` of dense `a`,
/// rows `i0..i1` (inclusive): A ← A H.
pub fn apply_reflector_cols<T: Scalar>(
    a: &mut Dense<T>,
    tau: T,
    v_tail: &[T],
    c0: usize,
    i0: usize,
    i1: usize,
) {
    if tau == T::zero() {
        return;
    }
    let m = v_tail.len() + 1;
    for i in i0..=i1 {
        let row = a.row_mut(i);
        let seg = &mut row[c0..c0 + m];
        let mut dot = seg[0];
        for (k, vi) in v_tail.iter().enumerate() {
            dot = vi.mul_add(seg[1 + k], dot);
        }
        let c = tau * dot;
        seg[0] = seg[0] - c;
        for (k, vi) in v_tail.iter().enumerate() {
            seg[1 + k] = seg[1 + k] - c * *vi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn norm(x: &[f64]) -> f64 {
        x.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    #[test]
    fn reflector_annihilates_tail() {
        let orig = vec![3.0, 4.0, 0.0, 12.0];
        let mut x = orig.clone();
        let tau = make_reflector(&mut x);
        // Apply H to the original vector: result must be (β, 0, 0, 0).
        let mut y = orig.clone();
        apply_reflector_vec(tau, &x[1..], &mut y);
        assert!((y[0].abs() - 13.0).abs() < 1e-12, "β = ±‖x‖, got {}", y[0]);
        for v in &y[1..] {
            assert!(v.abs() < 1e-12, "tail not annihilated: {y:?}");
        }
        // β stored in x[0] matches.
        assert!((y[0] - x[0]).abs() < 1e-12);
    }

    #[test]
    fn reflector_sign_avoids_cancellation() {
        let mut x = vec![5.0, 1e-8];
        let tau = make_reflector(&mut x);
        assert!(x[0] < 0.0, "β opposite sign of α");
        assert!(tau > 0.0);
    }

    #[test]
    fn zero_tail_gives_identity() {
        let mut x = vec![7.0, 0.0, 0.0];
        let tau = make_reflector(&mut x);
        assert_eq!(tau, 0.0);
        assert_eq!(x[0], 7.0); // untouched
    }

    #[test]
    fn reflector_preserves_norm() {
        let orig = vec![1.0, -2.0, 3.0, -4.0, 5.0];
        let mut x = orig.clone();
        let tau = make_reflector(&mut x);
        let mut y = orig.clone();
        apply_reflector_vec(tau, &x[1..], &mut y);
        assert!((norm(&y) - norm(&orig)).abs() < 1e-12);
    }

    #[test]
    fn reflector_is_orthogonal_on_other_vectors() {
        // Applying H twice must give back the original vector.
        let mut x = vec![2.0, -1.0, 0.5];
        let tau = make_reflector(&mut x);
        let orig = vec![0.3, 0.7, -0.2];
        let mut y = orig.clone();
        apply_reflector_vec(tau, &x[1..], &mut y);
        apply_reflector_vec(tau, &x[1..], &mut y);
        for (a, b) in y.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn dense_row_application_matches_vector_form() {
        let mut a = Dense::from_vec(3, 2, vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0]);
        let mut x = vec![1.0, 2.0, 3.0]; // column 0
        let tau = make_reflector(&mut x);
        let v = x[1..].to_vec();
        apply_reflector_rows(&mut a, tau, &v, 0, 0, 1);
        // Column 0 must now be (β, 0, 0).
        assert!((a.get(0, 0) - x[0]).abs() < 1e-12);
        assert!(a.get(1, 0).abs() < 1e-12);
        assert!(a.get(2, 0).abs() < 1e-12);
        // Column 1: compare against direct vector application.
        let mut col1 = vec![10.0, 20.0, 30.0];
        apply_reflector_vec(tau, &v, &mut col1);
        for i in 0..3 {
            assert!((a.get(i, 1) - col1[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn dense_col_application_matches_row_of_transpose() {
        let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut a = Dense::from_vec(2, 3, data.clone());
        let mut x = vec![1.0, 2.0, 3.0]; // row 0
        let tau = make_reflector(&mut x);
        let v = x[1..].to_vec();
        apply_reflector_cols(&mut a, tau, &v, 0, 0, 1);
        // Row 0 becomes (β, 0, 0).
        assert!((a.get(0, 0) - x[0]).abs() < 1e-12);
        assert!(a.get(0, 1).abs() < 1e-12);
        assert!(a.get(0, 2).abs() < 1e-12);
        // Row 1 equals vector application on the original row.
        let mut row1 = vec![4.0, 5.0, 6.0];
        apply_reflector_vec(tau, &v, &mut row1);
        for j in 0..3 {
            assert!((a.get(1, j) - row1[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn simd_reflector_matches_scalar_bitwise_without_contraction() {
        use crate::simd::{detect_isa, SimdIsa};
        let orig: Vec<f64> = (0..37).map(|i| (i as f64 * 0.731 - 11.0) / 3.0).collect();
        for isa in [SimdIsa::Portable, detect_isa().unwrap_or(SimdIsa::Portable)] {
            let mut x_ref = orig.clone();
            let tau_ref = make_reflector(&mut x_ref);
            let mut x = orig.clone();
            let tau = make_reflector_simd(&mut x, SimdSpec::with_contract(isa, false));
            assert_eq!(tau.to_bits(), tau_ref.to_bits(), "{isa:?}");
            let same = x.iter().zip(&x_ref).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{isa:?}");
            // Contracted norm: not bitwise, but ulp-close and still a
            // valid reflector (tail annihilated).
            let mut xc = orig.clone();
            let tau_c = make_reflector_simd(&mut xc, SimdSpec::with_contract(isa, true));
            assert!((tau_c - tau_ref).abs() <= 16.0 * f64::EPSILON * tau_ref.abs());
            let mut y = orig.clone();
            apply_reflector_vec(tau_c, &xc[1..], &mut y);
            for v in &y[1..] {
                assert!(v.abs() < 1e-12, "tail not annihilated under contraction");
            }
        }
        // Zero tail: identity on every path, x untouched.
        let mut z = vec![7.0f64, 0.0, 0.0];
        let spec = SimdSpec::with_contract(SimdIsa::Portable, true);
        assert_eq!(make_reflector_simd(&mut z, spec), 0.0);
        assert_eq!(z, vec![7.0, 0.0, 0.0]);
    }

    #[test]
    fn works_in_f32_and_f16() {
        use crate::scalar::F16;
        fn probe<T: Scalar>(tol: f64) {
            let orig: Vec<T> = [3.0, 4.0].iter().map(|&v| T::from_f64(v)).collect();
            let mut x = orig.clone();
            let tau = make_reflector(&mut x);
            let mut y = orig;
            apply_reflector_vec(tau, &x[1..], &mut y);
            assert!((y[0].to_f64().abs() - 5.0).abs() < tol);
            assert!(y[1].to_f64().abs() < tol);
        }
        probe::<f32>(1e-5);
        probe::<F16>(2e-2);
    }
}
