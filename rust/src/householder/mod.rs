//! Householder reflectors — the numerical core of every stage.
//!
//! Conventions follow LAPACK `larfg`: a reflector `H = I − τ v vᵀ` with
//! `v[0] = 1` maps a vector `x` to `(β, 0, …, 0)ᵀ`. Near-zero tails give
//! `τ = 0` (H = I), matching the treatment of near-zero elements in the
//! tile-QR work the paper builds on [11].

mod reflector;

pub use reflector::{
    apply_reflector_cols, apply_reflector_rows, apply_reflector_vec, make_reflector,
    make_reflector_simd,
};
