//! Fig. 6 regenerator: GPU-style reduction vs CPU-library baselines.
//!
//! Two complementary comparisons, each with explicit provenance:
//!
//! 1. **measured / measured** — our tiled launch-parallel reduction vs
//!    PLASMA-style and SLATE-style baselines, all run natively on this
//!    host (scaled sizes). Shows the algorithmic win of tiling +
//!    pipelining at identical hardware.
//! 2. **modeled-GPU / measured-CPU** — the H100 hardware model vs the
//!    measured baselines, the analog of the paper's single-GPU vs
//!    single-CPU ratios (who wins, by roughly what factor).

use banded_svd::banded::storage::Banded;
use banded_svd::baselines::{plasma_like_reduce, slate_like_reduce};
use banded_svd::bulge::reduce_to_bidiagonal_parallel;
use banded_svd::config::TuneParams;
use banded_svd::generate::random_banded;
use banded_svd::simulator::{hw, simulate_reduction};
use banded_svd::util::bench::{fmt_duration, Table};
use banded_svd::util::json::{write_experiment, Json};
use banded_svd::util::rng::Xoshiro256;
use banded_svd::util::threadpool::ThreadPool;
use std::time::{Duration, Instant};

fn time_once(f: impl FnOnce()) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed()
}

fn main() {
    let fast = std::env::var("BSVD_BENCH_FAST").ok().as_deref() == Some("1");
    let sizes: &[usize] = if fast { &[256, 512] } else { &[256, 512, 1024, 2048] };
    let bandwidths: &[usize] = if fast { &[16] } else { &[8, 16, 32, 64] };
    let pool = ThreadPool::new(0);
    println!("=== Fig. 6: runtime ratios vs CPU baselines ===");
    println!("(paper: 1k-32k, bw 32-512; scaled to {sizes:?} x {bandwidths:?})\n");
    let mut t = Table::new(vec![
        "n", "bw", "ours(par)", "plasma-like", "slate-like", "plasma/ours", "slate/ours",
        "modelH100", "plasma/model", "slate/model",
    ]);
    let mut arr = Vec::new();
    for &n in sizes {
        for &bw in bandwidths {
            if bw >= n / 4 {
                continue;
            }
            let mut rng = Xoshiro256::seed_from_u64((n + bw) as u64);
            let tw = (bw / 2).max(1);
            let params = TuneParams { tpb: 32, tw, max_blocks: 4096 };
            let base = random_banded::<f64>(n, bw, bw - 1, &mut rng);
            let dense = base.to_dense();

            let mut ours = Banded::from_dense(&dense, n, bw, tw);
            let t_ours =
                time_once(|| drop(reduce_to_bidiagonal_parallel(&mut ours, bw, &params, &pool)));

            let mut plasma = Banded::from_dense(&dense, n, bw, bw - 1);
            let t_plasma = time_once(|| plasma_like_reduce(&mut plasma, bw, &pool, 4));

            let mut slate = Banded::from_dense(&dense, n, bw, bw - 1);
            let t_slate = time_once(|| slate_like_reduce(&mut slate, bw));

            let model = simulate_reduction(&hw::H100, 4, n, bw, &params).seconds;

            t.row(vec![
                n.to_string(),
                bw.to_string(),
                fmt_duration(t_ours),
                fmt_duration(t_plasma),
                fmt_duration(t_slate),
                format!("{:.2}x", t_plasma.as_secs_f64() / t_ours.as_secs_f64()),
                format!("{:.2}x", t_slate.as_secs_f64() / t_ours.as_secs_f64()),
                format!("{:.1} ms", model * 1e3),
                format!("{:.1}x", t_plasma.as_secs_f64() / model),
                format!("{:.1}x", t_slate.as_secs_f64() / model),
            ]);
            arr.push(
                Json::obj()
                    .set("n", n)
                    .set("bw", bw)
                    .set("ours_s", t_ours.as_secs_f64())
                    .set("plasma_s", t_plasma.as_secs_f64())
                    .set("slate_s", t_slate.as_secs_f64())
                    .set("model_h100_s", model),
            );
        }
    }
    t.print();
    println!("\nexpected shape (paper): ratios grow with n and shrink with bw; the");
    println!("GPU(-model) advantage is largest at small bandwidths and large matrices.");
    let path = write_experiment("fig6_libraries", &Json::Arr(arr)).unwrap();
    println!("[json] {}", path.display());
}
