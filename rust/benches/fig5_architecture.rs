//! Fig. 5 regenerator: relative performance loss of older architectures
//! (A100 vs H100, MI250X vs MI300X) across sizes and bandwidths.

use banded_svd::config::TuneParams;
use banded_svd::simulator::{hw, simulate_reduction};
use banded_svd::util::bench::Table;
use banded_svd::util::json::{write_experiment, Json};

fn main() {
    println!("=== Fig. 5: architecture generation gains (modeled) ===");
    println!("values are time(old)/time(new): > 1 means the newer GPU wins\n");
    let sizes = [4096usize, 8192, 16384, 32768, 65536];
    let bandwidths = [32usize, 128];
    let mut arr = Vec::new();
    for &bw in &bandwidths {
        let tw = 32.min(bw - 1);
        let p = TuneParams { tpb: 32, tw, max_blocks: 192 };
        let mut t = Table::new(vec!["n", "A100/H100", "MI250X/MI300X"]);
        for &n in &sizes {
            let h100 = simulate_reduction(&hw::H100, 4, n, bw, &p).seconds;
            let a100 = simulate_reduction(&hw::A100, 4, n, bw, &p).seconds;
            let mi300 = simulate_reduction(&hw::MI300X, 4, n, bw, &p).seconds;
            let mi250 = simulate_reduction(&hw::MI250X, 4, n, bw, &p).seconds;
            t.row(vec![
                n.to_string(),
                format!("{:.2}x", a100 / h100),
                format!("{:.2}x", mi250 / mi300),
            ]);
            arr.push(
                Json::obj()
                    .set("n", n)
                    .set("bw", bw)
                    .set("nvidia_gain", a100 / h100)
                    .set("amd_gain", mi250 / mi300),
            );
        }
        println!("--- bandwidth {bw} ---");
        t.print();
        println!();
    }
    println!("expected shape: both ratios > 1 (newer architectures win), driven by");
    println!("H100's larger L1/L2 and MI300X's doubled L1 + Infinity Cache (paper §V-C).");
    let path = write_experiment("fig5_architecture", &Json::Arr(arr)).unwrap();
    println!("[json] {}", path.display());
}
