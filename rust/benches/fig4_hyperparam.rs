//! Fig. 4 regenerator: brute-force hyperparameter sweep (MaxBlocks ×
//! tilewidth × TPB) on the hardware model — H100 FP32/FP64 and MI300X
//! FP32, bandwidths 32 and 128 (the paper's parallel-coordinates data).

use banded_svd::config::TuneParams;
use banded_svd::simulator::{hw, simulate_reduction};
use banded_svd::util::bench::Table;
use banded_svd::util::json::{write_experiment, Json};

fn main() {
    println!("=== Fig. 4: hyperparameter sweep (modeled relative runtimes) ===\n");
    let cases = [
        ("H100", 4usize, 32usize, 65536usize),
        ("H100", 4, 128, 65536),
        ("H100", 8, 32, 65536),
        ("H100", 8, 128, 65536),
        ("MI300X", 4, 32, 65536),
        ("MI300X", 4, 128, 32768),
    ];
    let mut arr = Vec::new();
    for (arch_name, es, bw, n) in cases {
        let arch = hw::arch_by_name(arch_name).unwrap();
        let prec = match es {
            8 => "fp64",
            2 => "fp16",
            _ => "fp32",
        };
        println!("--- {arch_name} {prec} bw={bw} n={n} ---");
        let mut best = (f64::INFINITY, TuneParams::default());
        let mut results = Vec::new();
        for mb in [48usize, 96, 192, 384] {
            for tw in [8usize, 16, 32, 64] {
                if tw >= bw {
                    continue;
                }
                for tpb in [16usize, 32, 64] {
                    let p = TuneParams { tpb, tw, max_blocks: mb };
                    let s = simulate_reduction(&arch, es, n, bw, &p).seconds;
                    if s < best.0 {
                        best = (s, p);
                    }
                    results.push((mb, tw, tpb, s));
                }
            }
        }
        let mut t = Table::new(vec!["maxblk", "tw", "tpb", "time", "rel"]);
        for (mb, tw, tpb, s) in &results {
            t.row(vec![
                mb.to_string(),
                tw.to_string(),
                tpb.to_string(),
                format!("{s:.3} s"),
                format!("{:.2}x", s / best.0),
            ]);
            arr.push(
                Json::obj()
                    .set("arch", arch_name)
                    .set("precision", prec)
                    .set("bw", bw)
                    .set("max_blocks", *mb)
                    .set("tw", *tw)
                    .set("tpb", *tpb)
                    .set("seconds", *s),
            );
        }
        t.print();
        println!(
            "best: max_blocks={} tw={} tpb={} — paper optimum tw: {} ({prec})\n",
            best.1.max_blocks,
            best.1.tw,
            best.1.tpb,
            128 / es
        );
    }
    let path = write_experiment("fig4_hyperparam", &Json::Arr(arr)).unwrap();
    println!("[json] {}", path.display());
}
