//! Service throughput sweep: jobs/sec of the reduction service as the
//! micro-batch window and the number of concurrent submitters grow
//! (1 → 64), against the solo-submission baseline (window 0, one job per
//! flush). Dynamic micro-batching pays off exactly where the batch
//! engine does — merged flushes fill shared launches the solo path
//! leaves empty — so merged-window throughput must meet or beat solo
//! throughput once ≥ 8 submitters keep the queue non-empty (the
//! acceptance line this bench prints).
//!
//! Honours BSVD_BENCH_FAST=1 (smaller sweep, fewer jobs).

use banded_svd::banded::storage::Banded;
use banded_svd::client::{Client, LocalClient, ReductionRequest};
use banded_svd::config::{
    BackendKind, BatchConfig, PackingPolicy, ServiceConfig, ShardRouting, TuneParams,
};
use banded_svd::generate::random_banded;
use banded_svd::util::bench::Table;
use banded_svd::util::json::{write_experiment, Json};
use banded_svd::util::rng::Xoshiro256;
use std::time::{Duration, Instant};

/// Drive the load through the unified client in queued mode: the client
/// embeds the in-process service, and every submitter thread shares the
/// same `&dyn Client` surface a remote caller would use.
fn run_load(cfg: &ServiceConfig, base: &[Banded<f64>], bw: usize, submitters: usize) -> (f64, f64) {
    let client = LocalClient::queued(cfg.clone()).expect("client start");
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for s in 0..submitters {
            let client = &client;
            scope.spawn(move || {
                let mut job = s;
                while job < base.len() {
                    let request =
                        ReductionRequest::new().problem((base[job].clone(), bw));
                    let outcome = client.submit_wait(request).expect("job failed");
                    assert_eq!(outcome.problems[0].sv.len(), base[job].n());
                    job += submitters;
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = client.service().expect("queued mode").stats();
    assert_eq!(stats.jobs_completed as usize, base.len());
    assert_eq!(client.stats().jobs_completed as usize, base.len());
    (base.len() as f64 / wall, stats.avg_batch_jobs)
}

fn main() {
    let fast = std::env::var("BSVD_BENCH_FAST").ok().as_deref() == Some("1");
    let (n, bw) = (256usize, 16usize);
    let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };
    let jobs = if fast { 24 } else { 96 };
    let submitter_counts: &[usize] = if fast { &[1, 8] } else { &[1, 2, 4, 8, 16, 32, 64] };
    let windows_us: &[u64] = if fast { &[0, 500] } else { &[0, 200, 500, 2000] };

    println!("=== service throughput: jobs/sec vs batch window × submitters ===");
    println!("(n={n}, bw={bw}, f64, threadpool backend, {jobs} jobs per cell)\n");

    let mut rng = Xoshiro256::seed_from_u64(77);
    let tw = params.effective_tw(bw);
    let base: Vec<Banded<f64>> =
        (0..jobs).map(|_| random_banded::<f64>(n, bw, tw, &mut rng)).collect();

    let cfg = |window_us: u64, max_coresident: usize, workers: usize| ServiceConfig {
        params,
        batch: BatchConfig { max_coresident, policy: PackingPolicy::RoundRobin },
        backend: BackendKind::Threadpool,
        threads: 0,
        window: Duration::from_micros(window_us),
        queue_cap: jobs.max(64),
        backlog_cap_s: 1e9,
        cache_cap: 64,
        arch: "H100",
        workers,
        routing: ShardRouting::LeastLoaded,
        quota_pending_cap: 0,
        vectors_cap_n: banded_svd::config::DEFAULT_VECTORS_CAP_N,
    };

    let mut table = Table::new(vec!["submitters", "window µs", "jobs/s", "avg batch", "vs solo"]);
    let mut arr = Vec::new();
    let mut merged_beats_solo_at_8 = None;
    for &submitters in submitter_counts {
        // Solo baseline: no window, one job per flush — every submission
        // executes alone, as if each request ran the pipeline directly.
        let (solo_tput, _) = run_load(&cfg(0, 1, 1), &base, bw, submitters);
        table.row(vec![
            submitters.to_string(),
            "solo".to_string(),
            format!("{solo_tput:.1}"),
            "1.00".to_string(),
            "1.00x".to_string(),
        ]);
        for &window_us in windows_us {
            let (tput, avg_batch) = run_load(&cfg(window_us, 16, 1), &base, bw, submitters);
            let ratio = tput / solo_tput.max(1e-9);
            if submitters == 8 && window_us > 0 && merged_beats_solo_at_8.is_none() {
                merged_beats_solo_at_8 = Some(ratio);
            }
            table.row(vec![
                submitters.to_string(),
                window_us.to_string(),
                format!("{tput:.1}"),
                format!("{avg_batch:.2}"),
                format!("{ratio:.2}x"),
            ]);
            arr.push(
                Json::obj()
                    .set("submitters", submitters)
                    .set("window_us", Json::Int(window_us as i64))
                    .set("jobs_per_s", tput)
                    .set("avg_batch_jobs", avg_batch)
                    .set("vs_solo", ratio),
            );
        }
    }
    table.print();

    // Shard scaling: the same merged-window load spread over N batcher
    // workers, each with its own backend executor.
    let shard_counts: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4] };
    let shard_submitters = 16usize;
    println!("\n=== worker shards: window 500µs, {shard_submitters} submitters ===");
    let mut shard_table = Table::new(vec!["workers", "jobs/s", "avg batch"]);
    let mut shard_arr = Vec::new();
    for &workers in shard_counts {
        let (tput, avg_batch) = run_load(&cfg(500, 16, workers), &base, bw, shard_submitters);
        shard_table.row(vec![
            workers.to_string(),
            format!("{tput:.1}"),
            format!("{avg_batch:.2}"),
        ]);
        shard_arr.push(
            Json::obj()
                .set("workers", workers)
                .set("jobs_per_s", tput)
                .set("avg_batch_jobs", avg_batch),
        );
    }
    shard_table.print();

    if let Some(ratio) = merged_beats_solo_at_8 {
        println!(
            "\nmerged-window vs solo at 8 submitters: {ratio:.2}x \
             (acceptance: >= 1.0x once batching engages)"
        );
    }
    let json = Json::obj()
        .set("experiment", "service_throughput")
        .set("n", n)
        .set("bw", bw)
        .set("jobs", jobs)
        .set("results", Json::Arr(arr))
        .set("shard_results", Json::Arr(shard_arr));
    match write_experiment("service_throughput", &json) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write experiment json: {e}"),
    }
}
