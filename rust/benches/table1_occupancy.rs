//! Table I regenerator: matrix size for full GPU occupancy (eq. (1)).

use banded_svd::simulator::{self, occupancy};
use banded_svd::util::bench::Table;
use banded_svd::util::json::{write_experiment, Json};

fn main() {
    println!("=== Table I: matrix size n required for full occupancy (CBW = 32) ===");
    let rows = simulator::table1(32);
    let mut t = Table::new(vec!["GPU Architecture", "Execution Units (ALUs)", "n >= 3*CBW*ALUs"]);
    let mut arr = Vec::new();
    for r in &rows {
        t.row(vec![r.arch.to_string(), r.alus.to_string(), r.n_required.to_string()]);
        arr.push(
            Json::obj()
                .set("arch", r.arch)
                .set("alus", r.alus)
                .set("n_required", r.n_required),
        );
    }
    t.print();
    // Occupancy fractions at the paper's benchmark sizes.
    println!("\noccupancy fraction on H100 at CBW=32:");
    for n in [1024usize, 8192, 32768, 65536] {
        println!(
            "  n = {n:>6}: {:.1}%",
            100.0 * occupancy::occupancy_fraction(&banded_svd::simulator::hw::H100, n, 32)
        );
    }
    let path = write_experiment("table1_occupancy", &Json::Arr(arr)).unwrap();
    println!("\n[json] {}", path.display());
}
