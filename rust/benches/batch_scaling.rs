//! Batch-scaling sweep: problems/sec of the interleaved batch engine as
//! the batch size grows 1 → 64 (n = 512, bw = 32, f64, parallel native
//! backend), driven through the unified client front door. The
//! single-problem launch loop leaves most of the MaxBlocks capacity idle
//! at this size (Table I: full occupancy needs much larger n);
//! co-scheduling K problems fills the shared launches, so throughput
//! rises with K until the capacity saturates.
//!
//! Timing uses `ReductionOutcome::wall` — the client measures execution
//! only, excluding request assembly and backend construction.
//!
//! Honours BSVD_BENCH_FAST=1 (smaller sweep, fewer trials).

use banded_svd::banded::storage::Banded;
use banded_svd::client::{Client, LocalClient, ReductionRequest};
use banded_svd::config::{BackendKind, BatchConfig, PackingPolicy, TuneParams};
use banded_svd::generate::random_banded;
use banded_svd::util::bench::{fmt_duration, Table};
use banded_svd::util::json::{write_experiment, Json};
use banded_svd::util::rng::Xoshiro256;
use std::time::Duration;

fn main() {
    let fast = std::env::var("BSVD_BENCH_FAST").ok().as_deref() == Some("1");
    let (n, bw) = (512usize, 32usize);
    let params = TuneParams { tpb: 32, tw: 16, max_blocks: 192 };
    let tw = params.effective_tw(bw);
    let batch_sizes: &[usize] = if fast { &[1, 4, 16] } else { &[1, 2, 4, 8, 16, 32, 64] };
    let trials = if fast { 2 } else { 3 };
    let max_k = *batch_sizes.last().unwrap();

    println!("=== batch scaling: problems/sec vs batch size (client front door) ===");
    println!("(n={n}, bw={bw}, tw={tw}, f64, parallel native, MaxBlocks={})\n", params.max_blocks);

    let mut rng = Xoshiro256::seed_from_u64(512);
    let base: Vec<Banded<f64>> =
        (0..max_k).map(|_| random_banded::<f64>(n, bw, tw, &mut rng)).collect();

    let mut table = Table::new(vec![
        "batch",
        "policy",
        "wall",
        "problems/s",
        "shared launches",
        "occupancy",
        "speedup",
    ]);
    let mut arr = Vec::new();
    let mut tput_1 = 0.0f64;
    let mut tput_16 = 0.0f64;
    for &k in batch_sizes {
        for policy in [PackingPolicy::RoundRobin, PackingPolicy::GreedyFill] {
            let cfg = BatchConfig { max_coresident: max_k, policy };
            let client = LocalClient::direct(params, cfg, BackendKind::Threadpool, 0)
                .expect("threadpool client");
            let mut best = Duration::MAX;
            let mut launches = 0usize;
            let mut occupancy = 0.0f64;
            for _ in 0..trials {
                let mut request = ReductionRequest::new();
                for a in &base[..k] {
                    request = request.problem((a.clone(), bw));
                }
                let outcome = client.submit_wait(request).expect("batched reduction failed");
                if outcome.wall < best {
                    best = outcome.wall;
                }
                let batch = outcome.batch.as_ref().expect("direct mode reports batch metrics");
                launches = batch.aggregate.launches;
                occupancy = batch.occupancy_ratio();
                for (i, p) in outcome.problems.iter().enumerate() {
                    assert_eq!(
                        p.residual_off_band,
                        Some(0.0),
                        "batch {k}: problem {i} not reduced"
                    );
                }
            }
            let tput = k as f64 / best.as_secs_f64().max(1e-9);
            if k == 1 && policy == PackingPolicy::RoundRobin {
                tput_1 = tput;
            }
            if k == 16 && policy == PackingPolicy::RoundRobin {
                tput_16 = tput;
            }
            let speedup = if tput_1 > 0.0 { tput / tput_1 } else { 1.0 };
            let policy_name = match policy {
                PackingPolicy::RoundRobin => "round-robin",
                PackingPolicy::GreedyFill => "greedy-fill",
            };
            table.row(vec![
                k.to_string(),
                policy_name.to_string(),
                fmt_duration(best),
                format!("{tput:.1}"),
                launches.to_string(),
                format!("{occupancy:.2}"),
                format!("{speedup:.2}x"),
            ]);
            arr.push(
                Json::obj()
                    .set("batch", k)
                    .set("policy", policy_name)
                    .set("wall_s", best.as_secs_f64())
                    .set("problems_per_s", tput)
                    .set("shared_launches", launches)
                    .set("occupancy", occupancy),
            );
        }
    }
    table.print();
    if tput_1 > 0.0 && tput_16 > 0.0 {
        println!(
            "\nbatch-16 throughput / batch-1 throughput = {:.2}x (target: >= 2x)",
            tput_16 / tput_1
        );
    }
    let json = Json::obj()
        .set("experiment", "batch_scaling")
        .set("n", n)
        .set("bw", bw)
        .set("tw", tw)
        .set("max_blocks", params.max_blocks)
        .set("results", Json::Arr(arr));
    match write_experiment("batch_scaling", &json) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write experiment json: {e}"),
    }
}
