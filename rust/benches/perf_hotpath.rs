//! §Perf harness: micro-benchmarks of the hot paths at each layer,
//! driving the EXPERIMENTS.md §Perf before/after log.
//!
//! - L3 native: single cycle kernel, launch loop (seq vs parallel),
//!   thread scaling.
//! - PJRT path: per-cycle vs fused whole-stage artifacts (needs
//!   `make artifacts`).

use banded_svd::bulge::cycle::{
    exec_cycle_inplace, exec_cycle_packed, exec_cycle_packed_with, stage_uses_packed,
    CycleWorkspace, SharedBanded,
};
use banded_svd::bulge::schedule::Stage;
use banded_svd::bulge::{reduce_to_bidiagonal, reduce_to_bidiagonal_parallel};
use banded_svd::config::TuneParams;
use banded_svd::generate::random_banded;
use banded_svd::runtime::{artifact_dir, PjrtEngine};
use banded_svd::simd::{detect_isa, SimdSpec};
use banded_svd::util::bench::{fmt_duration, Bencher, Table};
use banded_svd::util::json::{write_experiment, Json};
use banded_svd::util::rng::Xoshiro256;
use banded_svd::util::threadpool::ThreadPool;

/// Which cycle-kernel arm a timing run exercises.
#[derive(Copy, Clone)]
enum Arm {
    Inplace,
    PackedScalar,
    PackedSimd(SimdSpec),
}

fn main() {
    let bench = Bencher::from_env();
    println!("=== perf: hot-path micro-benchmarks ===\n");

    // --- L1-analog: cycle kernel cost, in-place vs packed vs SIMD ---------
    // Measuring one task repeatedly would hit the tau=0 fast path after
    // the first call; instead run a whole stage sweep-major on a fresh
    // matrix and divide by the task count. All arms execute the exact
    // same float ops (results are bitwise identical); the packed arms
    // gather each cycle's footprint into a contiguous per-worker tile,
    // chase there, and write back once — the SIMD arm additionally runs
    // the tile chase through the lane kernels (the `--backend simd` hot
    // path). Acceptance bars: packed no slower than in-place at bw ≥ 64
    // (the default gate routes b + d ≥ 48 through the packed path), and
    // SIMD no slower than packed-scalar above that same gate.
    let simd_spec = SimdSpec::resolve("force", false, detect_isa());
    println!("simd lane kernels: {}\n", simd_spec.describe());
    let reps = if std::env::var("BSVD_BENCH_FAST").ok().as_deref() == Some("1") {
        2
    } else {
        5
    };
    let mut t = Table::new(vec![
        "kernel", "in-place/task", "packed/task", "simd/task", "simd/packed", "default path",
    ]);
    let mut kernel_rows = Vec::new();
    for (b, d) in [(16usize, 8usize), (32, 16), (64, 32), (96, 48), (128, 64)] {
        let stage = Stage::new(b, d);
        let n = 16 * b;
        let mut rng = Xoshiro256::seed_from_u64(1);
        let base = random_banded::<f64>(n, b, d, &mut rng);
        let tasks: usize = (0..stage.num_sweeps(n)).map(|k| stage.cmax(n, k) + 1).sum();
        let run = |arm: Arm| {
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let mut a = base.clone();
                let mut ws = CycleWorkspace::new(&stage);
                let view = SharedBanded::new(&mut a);
                let t0 = std::time::Instant::now();
                for k in 0..stage.num_sweeps(n) {
                    for c in 0..=stage.cmax(n, k) {
                        let task = stage.task(k, c);
                        // SAFETY: exclusive access, single thread.
                        unsafe {
                            match arm {
                                Arm::Inplace => exec_cycle_inplace(&view, &stage, &task, &mut ws),
                                Arm::PackedScalar => {
                                    exec_cycle_packed(&view, &stage, &task, &mut ws)
                                }
                                Arm::PackedSimd(spec) => {
                                    exec_cycle_packed_with(&view, &stage, &task, &mut ws, spec)
                                }
                            }
                        }
                    }
                }
                best = best.min(t0.elapsed().as_secs_f64() / tasks as f64);
            }
            best
        };
        let inplace = run(Arm::Inplace);
        let packed = run(Arm::PackedScalar);
        let simd = run(Arm::PackedSimd(simd_spec));
        t.row(vec![
            format!("cycle b={b} d={d}"),
            format!("{:.0} ns", inplace * 1e9),
            format!("{:.0} ns", packed * 1e9),
            format!("{:.0} ns", simd * 1e9),
            format!("{:.2}x", simd / packed),
            if stage_uses_packed(&stage) { "packed".into() } else { "in-place".into() },
        ]);
        kernel_rows.push(
            Json::obj()
                .set("b", b)
                .set("d", d)
                .set("inplace_ns", inplace * 1e9)
                .set("scalar_ns", packed * 1e9)
                .set("simd_ns", simd * 1e9),
        );
    }
    t.print();

    // --- L3: full reduction, sequential vs parallel, two workload sizes --
    // Small launches (n=2048, bw=32): barrier overhead ~ per-launch work,
    // parallel gains little — the CPU analog of the paper's occupancy
    // argument. Bigger tasks (n=4096, bw=64): launch-level parallelism
    // pays off.
    for (n, bw, tw) in [(2048usize, 32usize, 16usize), (4096, 64, 32)] {
        println!("\n--- launch loop: n={n}, bw={bw}, tw={tw} ---");
        let params = TuneParams { tpb: 32, tw, max_blocks: 4096 };
        let mut rng = Xoshiro256::seed_from_u64(2);
        let base = random_banded::<f64>(n, bw, tw, &mut rng);
        let mut t = Table::new(vec!["executor", "median"]);
        let s = bench.run_once("sequential", || {
            let mut a = base.clone();
            reduce_to_bidiagonal(&mut a, bw, &params);
        });
        t.row(vec!["sequential".to_string(), fmt_duration(s.median)]);
        let seq = s.median;
        for threads in [2usize, 4, 8] {
            let pool = ThreadPool::new(threads);
            let s = bench.run_once(&format!("parallel x{threads}"), || {
                let mut a = base.clone();
                reduce_to_bidiagonal_parallel(&mut a, bw, &params, &pool);
            });
            t.row(vec![
                format!(
                    "parallel x{threads} ({:.2}x)",
                    seq.as_secs_f64() / s.median.as_secs_f64()
                ),
                fmt_duration(s.median),
            ]);
        }
        t.print();
    }

    // --- PJRT path: per-cycle vs fused ------------------------------------
    println!("\n--- PJRT artifacts (n=256, bw=8, tw=4) ---");
    match PjrtEngine::load(&artifact_dir(), 256, 8, 4) {
        Ok(engine) => {
            let mut rng = Xoshiro256::seed_from_u64(3);
            let a0 = random_banded::<f32>(256, 8, 4, &mut rng);
            let mut t = Table::new(vec!["mode", "median", "launches"]);
            let mut a = a0.clone();
            let s = bench.run_once("per-cycle", || {
                engine.reduce_banded(&mut a, false).unwrap();
            });
            let launches: usize = engine.manifest().stages.iter().map(|s| s.launches).sum();
            t.row(vec!["per-cycle".into(), fmt_duration(s.median), launches.to_string()]);
            let per_cycle = s.median;
            let mut a = a0.clone();
            let s = bench.run_once("fused", || {
                engine.reduce_banded(&mut a, true).unwrap();
            });
            t.row(vec![
                format!("fused ({:.1}x)", per_cycle.as_secs_f64() / s.median.as_secs_f64()),
                fmt_duration(s.median),
                format!("{} (in {} calls)", launches, engine.manifest().stages.len()),
            ]);
            t.print();
        }
        Err(e) => println!("skipped (artifacts missing: {e})"),
    }

    // Machine-readable per-kernel numbers for `banded-svd bench-collect`
    // (the measured perf trajectory: BENCH_PR7.json and the CI gate).
    let json = Json::obj()
        .set("experiment", "perf_hotpath")
        .set("simd", simd_spec.describe())
        .set("packed_kernels", Json::Arr(kernel_rows));
    match write_experiment("perf_hotpath", &json) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write experiment json: {e}"),
    }
}
