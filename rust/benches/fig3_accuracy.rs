//! Fig. 3 regenerator (measured, not modeled): relative singular-value
//! error of the mixed-precision pipeline across sizes, bandwidths,
//! spectra and precisions. Sizes are scaled down from the paper's
//! 2k–16k to keep the full protocol runnable on this testbed
//! (substitution documented in DESIGN.md §2).

use banded_svd::config::TuneParams;
use banded_svd::generate::{dense_with_spectrum, Spectrum};
use banded_svd::pipeline::{relative_sv_error, singular_values_3stage_mixed, SvdOptions};
use banded_svd::scalar::F16;
use banded_svd::util::bench::Table;
use banded_svd::util::json::{write_experiment, Json};
use banded_svd::util::rng::Xoshiro256;

fn main() {
    let fast = std::env::var("BSVD_BENCH_FAST").ok().as_deref() == Some("1");
    let sizes: &[usize] = if fast { &[64, 128] } else { &[64, 128, 256, 384] };
    let bandwidths: &[usize] = if fast { &[16] } else { &[8, 16, 32] };
    let trials = if fast { 1 } else { 3 };
    println!("=== Fig. 3: relative error of singular values (measured) ===");
    println!("(paper sizes 2k-16k scaled to {sizes:?}; {trials} trials/cell)\n");
    let mut t = Table::new(vec!["n", "bw", "spectrum", "fp64", "fp32", "fp16"]);
    let mut arr = Vec::new();
    for &n in sizes {
        for &bw in bandwidths {
            if bw >= n / 2 {
                continue;
            }
            for spectrum in Spectrum::ALL {
                let mut e = [0.0f64; 3];
                for trial in 0..trials {
                    let mut rng =
                        Xoshiro256::seed_from_u64(7 + trial as u64 * 997 + (n * bw) as u64);
                    let sigma = spectrum.sample(n, &mut rng);
                    let a = dense_with_spectrum(n, &sigma, &mut rng, 48);
                    let opts = SvdOptions {
                        bandwidth: bw,
                        params: TuneParams { tpb: 32, tw: (bw / 2).max(1), max_blocks: 192 },
                    };
                    let (s64, _) = singular_values_3stage_mixed::<f64>(&a, &opts);
                    let (s32, _) = singular_values_3stage_mixed::<f32>(&a, &opts);
                    let (s16, _) = singular_values_3stage_mixed::<F16>(&a, &opts);
                    e[0] += relative_sv_error(&s64, &sigma) / trials as f64;
                    e[1] += relative_sv_error(&s32, &sigma) / trials as f64;
                    e[2] += relative_sv_error(&s16, &sigma) / trials as f64;
                }
                t.row(vec![
                    n.to_string(),
                    bw.to_string(),
                    spectrum.name().to_string(),
                    format!("{:.2e}", e[0]),
                    format!("{:.2e}", e[1]),
                    format!("{:.2e}", e[2]),
                ]);
                arr.push(
                    Json::obj()
                        .set("n", n)
                        .set("bw", bw)
                        .set("spectrum", spectrum.name())
                        .set("fp64", e[0])
                        .set("fp32", e[1])
                        .set("fp16", e[2]),
                );
            }
        }
    }
    t.print();
    println!("\nexpected shape: fp64 ~ machine-eps; fp32 size-dependent; fp16 largest,");
    println!("best on well-behaved (arithmetic) spectra; bandwidth has little effect.");
    let path = write_experiment("fig3_accuracy", &Json::Arr(arr)).unwrap();
    println!("[json] {}", path.display());
}
