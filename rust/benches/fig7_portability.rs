//! Fig. 7 regenerator: runtime scaling across vendors and precisions
//! (modeled), demonstrating the latency-linked-bandwidth ranking the
//! paper highlights (§V-E).

use banded_svd::config::TuneParams;
use banded_svd::simulator::{hw, simulate_reduction};
use banded_svd::util::bench::Table;
use banded_svd::util::json::{write_experiment, Json};

fn main() {
    println!("=== Fig. 7: cross-hardware / cross-precision scaling (modeled) ===\n");
    let sizes = [4096usize, 16384, 65536];
    let mut arr = Vec::new();
    for &bw in &[32usize, 128] {
        for (es, prec) in [(2usize, "fp16"), (4, "fp32"), (8, "fp64")] {
            let tw = (128 / es).min(bw - 1).max(1);
            let p = TuneParams { tpb: 32, tw, max_blocks: 192 };
            let mut t = Table::new(vec!["GPU", "n=4096", "n=16384", "n=65536"]);
            for arch in hw::all_archs() {
                let mut row = vec![arch.name.to_string()];
                for &n in &sizes {
                    let s = simulate_reduction(&arch, es, n, bw, &p).seconds;
                    row.push(format!("{s:.3} s"));
                    arr.push(
                        Json::obj()
                            .set("arch", arch.name)
                            .set("precision", prec)
                            .set("bw", bw)
                            .set("n", n)
                            .set("seconds", s),
                    );
                }
                t.row(row);
            }
            println!("--- bw={bw} {prec} (tw={tw}) ---");
            t.print();
            println!();
        }
    }
    println!("expected ranking (paper): H100 ≲ MI300X < A100/MI250X << PVC (~order of");
    println!("magnitude, despite PVC's larger caches) << M1 — L1/L2 latency-linked");
    println!("bandwidth, not capacity, is the determinant.");
    let path = write_experiment("fig7_portability", &Json::Arr(arr)).unwrap();
    println!("[json] {}", path.display());
}
