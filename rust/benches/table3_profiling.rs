//! Table III regenerator: modeled NSight-style kernel profile on RTX4060
//! across the paper's 8 hyperparameter configurations, plus the CUBLAS
//! geam streaming reference (§III-E).

use banded_svd::bulge::schedule::Stage;
use banded_svd::simulator::{hw, profile_geam_reference, profile_kernel};
use banded_svd::util::bench::Table;
use banded_svd::util::json::{write_experiment, Json};

fn main() {
    println!("=== Table III: kernel profiling on RTX4060 (modeled; n=32k, b=64) ===");
    // (tpb, max_blocks, tw) — the paper's grid, best config = (32,192,32).
    let grid = [
        (64usize, 48usize, 32usize),
        (64, 96, 32),
        (32, 96, 32),
        (32, 192, 32),
        (16, 192, 32),
        (32, 96, 16),
        (32, 192, 16),
        (64, 96, 16),
    ];
    let blocks = 32768 / (3 * 64);
    let mut t = Table::new(vec![
        "tpb", "maxblk", "tw", "time(us)", "mem%", "dram%", "l1%", "l2%", "cmp%", "warps/sm",
        "time/tw",
    ]);
    let mut arr = Vec::new();
    let mut best: Option<(f64, usize)> = None;
    for (i, &(tpb, mb, tw)) in grid.iter().enumerate() {
        let stage = Stage::new(64, tw);
        let m = profile_kernel(&hw::RTX4060, 4, &stage, tpb, mb, blocks);
        let per_tw = m.time_us / tw as f64;
        if best.map_or(true, |(b, _)| per_tw < b) {
            best = Some((per_tw, i));
        }
        t.row(vec![
            tpb.to_string(),
            mb.to_string(),
            tw.to_string(),
            format!("{:.0}", m.time_us),
            format!("{:.0}", m.memory_pct),
            format!("{:.0}", m.dram_pct),
            format!("{:.0}", m.l1_pct),
            format!("{:.0}", m.l2_pct),
            format!("{:.1}", m.compute_pct),
            format!("{:.2}", m.warps_per_sm),
            format!("{per_tw:.2}"),
        ]);
        arr.push(
            Json::obj()
                .set("tpb", tpb)
                .set("max_blocks", mb)
                .set("tw", tw)
                .set("time_us", m.time_us)
                .set("mem_pct", m.memory_pct)
                .set("dram_pct", m.dram_pct)
                .set("l1_pct", m.l1_pct)
                .set("l2_pct", m.l2_pct)
                .set("warps_per_sm", m.warps_per_sm),
        );
    }
    t.print();
    let (_, bi) = best.unwrap();
    println!(
        "\nbest overall (runtime / tilewidth): tpb={} max_blocks={} tw={} — paper: (32, 192, 32)",
        grid[bi].0, grid[bi].1, grid[bi].2
    );
    let g = profile_geam_reference(&hw::RTX4060, 4, 16384);
    println!(
        "geam B=A+Aᵀ reference: dram {:.0}% (paper ~78%), l1 {:.0}% / l2 {:.0}% (paper ~18%)",
        g.dram_pct, g.l1_pct, g.l2_pct
    );
    let path = write_experiment("table3_profiling", &Json::Arr(arr)).unwrap();
    println!("[json] {}", path.display());
}
