//! The batch engine's contract, property-tested: reducing K problems in
//! one interleaved batch yields **bitwise-identical** bidiagonals (f64,
//! native backend) to K independent single-problem coordinator runs —
//! across randomized problem counts, shapes, packing policies, and
//! admission-window sizes. Interleaving only reorders work *between*
//! problems; within a problem the launch order (and hence every float)
//! is untouched.

use banded_svd::banded::storage::Banded;
use banded_svd::batch::{BatchCoordinator, BatchInput};
use banded_svd::config::{BackendKind, BatchConfig, PackingPolicy, TuneParams};
use banded_svd::coordinator::Coordinator;
use banded_svd::generate::random_banded;
use banded_svd::util::prop::{check, Config};
use banded_svd::util::rng::Xoshiro256;

#[derive(Debug)]
struct Case {
    shapes: Vec<(usize, usize)>, // (n, bw)
    tw: usize,
    max_blocks: usize,
    policy: PackingPolicy,
    max_coresident: usize,
    seed: u64,
}

fn gen_case(rng: &mut Xoshiro256) -> Case {
    let k = rng.range_inclusive(2, 5);
    let shapes = (0..k)
        .map(|_| {
            let bw = rng.range_inclusive(2, 10);
            let n = rng.range_inclusive(bw + 4, 72);
            (n, bw)
        })
        .collect();
    Case {
        shapes,
        tw: rng.range_inclusive(1, 8),
        max_blocks: rng.range_inclusive(2, 48),
        policy: if rng.below(2) == 0 {
            PackingPolicy::RoundRobin
        } else {
            PackingPolicy::GreedyFill
        },
        max_coresident: rng.range_inclusive(1, 6),
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_batched_reduction_is_bitwise_equal_to_independent_runs() {
    let cfg = Config { cases: 32, ..Config::default() };
    check("batch-equals-solo", &cfg, gen_case, |case| {
        let params = TuneParams { tpb: 32, tw: case.tw, max_blocks: case.max_blocks };
        let mut rng = Xoshiro256::seed_from_u64(case.seed);
        let mats: Vec<Banded<f64>> = case
            .shapes
            .iter()
            .map(|&(n, bw)| random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng))
            .collect();

        // Batched: all problems co-scheduled into shared launches.
        let batch_cfg = BatchConfig { max_coresident: case.max_coresident, policy: case.policy };
        let batch_coord = BatchCoordinator::new(params, batch_cfg, 4);
        let mut inputs: Vec<BatchInput> = mats
            .iter()
            .zip(case.shapes.iter())
            .map(|(a, &(_, bw))| BatchInput::from((a.clone(), bw)))
            .collect();
        let report = batch_coord.run(&mut inputs).map_err(|e| e.to_string())?;

        // Independent: one coordinator run per problem.
        let solo_coord = Coordinator::new(params, 4);
        for (i, ((a, &(n, bw)), batched)) in mats
            .iter()
            .zip(case.shapes.iter())
            .zip(report.problems.iter())
            .enumerate()
        {
            let mut solo = a.clone();
            let solo_report = solo_coord
                .reduce_native(&mut solo, bw, BackendKind::Threadpool)
                .map_err(|e| e.to_string())?;
            if solo_report.diag != batched.diag {
                return Err(format!("problem {i} (n={n}, bw={bw}): diag differs"));
            }
            if solo_report.superdiag != batched.superdiag {
                return Err(format!("problem {i} (n={n}, bw={bw}): superdiag differs"));
            }
            if batched.residual_off_band != 0.0 {
                return Err(format!(
                    "problem {i} (n={n}, bw={bw}): residual {} after batched run",
                    batched.residual_off_band
                ));
            }
            if solo_report.metrics.launches != batched.metrics.launches
                || solo_report.metrics.tasks != batched.metrics.tasks
            {
                return Err(format!(
                    "problem {i}: per-problem metrics diverged (launches {} vs {}, tasks {} vs {})",
                    solo_report.metrics.launches,
                    batched.metrics.launches,
                    solo_report.metrics.tasks,
                    batched.metrics.tasks
                ));
            }
        }

        // Aggregate sanity: every task accounted for exactly once.
        let total: usize = report.problems.iter().map(|p| p.metrics.tasks).sum();
        if report.metrics.aggregate.tasks != total {
            return Err(format!(
                "aggregate tasks {} != sum of per-problem tasks {total}",
                report.metrics.aggregate.tasks
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_batched_sequential_oracle_agreement() {
    // Same contract against the *sequential* backend — ties the batch
    // engine to the sweep-order oracle through a second independent path.
    let cfg = Config { cases: 12, ..Config::default() };
    check("batch-equals-sequential", &cfg, gen_case, |case| {
        let params = TuneParams { tpb: 32, tw: case.tw, max_blocks: case.max_blocks };
        let mut rng = Xoshiro256::seed_from_u64(case.seed ^ 0xA5A5);
        let mats: Vec<Banded<f64>> = case
            .shapes
            .iter()
            .map(|&(n, bw)| random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng))
            .collect();
        let batch_cfg = BatchConfig { max_coresident: case.max_coresident, policy: case.policy };
        let batch_coord = BatchCoordinator::new(params, batch_cfg, 4);
        let mut inputs: Vec<BatchInput> = mats
            .iter()
            .zip(case.shapes.iter())
            .map(|(a, &(_, bw))| BatchInput::from((a.clone(), bw)))
            .collect();
        let report = batch_coord.run(&mut inputs).map_err(|e| e.to_string())?;
        let solo_coord = Coordinator::new(params, 1);
        for ((a, &(n, bw)), batched) in
            mats.iter().zip(case.shapes.iter()).zip(report.problems.iter())
        {
            let mut solo = a.clone();
            let solo_report = solo_coord
                .reduce_native(&mut solo, bw, BackendKind::Sequential)
                .map_err(|e| e.to_string())?;
            if solo_report.diag != batched.diag || solo_report.superdiag != batched.superdiag {
                return Err(format!("n={n}, bw={bw}: batched differs from sequential oracle"));
            }
        }
        Ok(())
    });
}
