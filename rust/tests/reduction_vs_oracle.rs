//! Cross-validation of the full reduction against the independent
//! one-sided Jacobi oracle (no shared code path) and against LAPACK-style
//! identities.

use banded_svd::banded::Dense;
use banded_svd::bulge::reduce_to_bidiagonal;
use banded_svd::config::TuneParams;
use banded_svd::generate::{dense_with_spectrum, random_banded, Spectrum};
use banded_svd::pipeline::{
    bidiagonal_singular_values, jacobi_singular_values, relative_sv_error,
};
use banded_svd::util::rng::Xoshiro256;

#[test]
fn tiled_reduction_singular_values_match_jacobi() {
    let mut rng = Xoshiro256::seed_from_u64(100);
    for (n, bw, tw) in [(64usize, 8usize, 4usize), (96, 12, 5), (48, 4, 3), (80, 16, 8)] {
        let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
        let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let dense = Dense::from_vec(n, n, a.to_dense());
        let res = reduce_to_bidiagonal(&mut a, bw, &params);
        let sv = bidiagonal_singular_values(&res.diag, &res.superdiag);
        let oracle = jacobi_singular_values(&dense);
        let err = relative_sv_error(&sv, &oracle);
        assert!(err < 1e-10, "n={n} bw={bw} tw={tw}: err {err}");
    }
}

#[test]
fn all_spectra_survive_the_full_pipeline() {
    let mut rng = Xoshiro256::seed_from_u64(101);
    let n = 64;
    for spectrum in Spectrum::ALL {
        let sigma = spectrum.sample(n, &mut rng);
        let a = dense_with_spectrum(n, &sigma, &mut rng, n);
        let opts = banded_svd::pipeline::SvdOptions {
            bandwidth: 8,
            params: TuneParams { tpb: 32, tw: 4, max_blocks: 192 },
        };
        let (sv, _) = banded_svd::pipeline::singular_values_3stage(&a, &opts);
        let err = relative_sv_error(&sv, &sigma);
        assert!(err < 1e-10, "{:?}: err {err}", spectrum);
    }
}

#[test]
fn schedule_statistics_match_occupancy_model() {
    // Peak launch parallelism must track n/(3·bw) (paper eq. (1) spacing)
    // through the coordinator for the *first* stage, where b = bw.
    use banded_svd::bulge::schedule::{stage_plan, Stage};
    let n = 3072;
    let bw = 16;
    let plan = stage_plan(bw, 8);
    let first: &Stage = &plan[0];
    let peak = (0..first.total_launches(n))
        .map(|t| first.tasks_at_count(n, t))
        .max()
        .unwrap();
    let expect = n / (3 * bw);
    assert!(
        (peak as i64 - expect as i64).abs() <= 2,
        "peak {peak} vs n/(3 bw) = {expect}"
    );
}

#[test]
fn wide_band_equals_narrow_band_spectrum() {
    // The same dense matrix pushed through different intermediate
    // bandwidths must give identical singular values — the trade-off the
    // paper's bandwidth-scaling claim rebalances.
    let mut rng = Xoshiro256::seed_from_u64(102);
    let n = 72;
    let sigma = Spectrum::Logarithmic.sample(n, &mut rng);
    let a = dense_with_spectrum(n, &sigma, &mut rng, n);
    let mut reference: Option<Vec<f64>> = None;
    for bw in [4usize, 8, 16, 32] {
        let opts = banded_svd::pipeline::SvdOptions {
            bandwidth: bw,
            params: TuneParams { tpb: 32, tw: (bw / 2).max(1), max_blocks: 192 },
        };
        let (sv, _) = banded_svd::pipeline::singular_values_3stage(&a, &opts);
        match &reference {
            None => reference = Some(sv),
            Some(r) => {
                let err = relative_sv_error(&sv, r);
                assert!(err < 1e-10, "bw={bw}: err {err}");
            }
        }
    }
}
