//! The load generator's contract, end to end:
//!
//! - **Plan determinism** — the rendered request stream (arrival
//!   instants, class picks, band-payload seeds, trace ids) is a pure
//!   function of `(seed, mix, process, duration)`: byte-identical across
//!   rebuilds, different under a different seed — property-tested over
//!   all four arrival families.
//! - **Exact reconciliation** — a run driven through
//!   `LocalClient::queued` reconciles attempt-for-attempt against the
//!   embedded service's own counters once the queue drains.
//! - **Overload shedding** — an open-loop load far above capacity, fired
//!   into a tiny queue, sheds only the *retryable* back-pressure kinds
//!   (`overloaded` / `quota-exceeded`), never deadlocks, and still
//!   reconciles at drain.
//! - **Binary band frames** — a proto-4 client shipping payloads as
//!   length-prefixed binary frames gets singular values bitwise
//!   identical to the inline-JSON path, over a real loopback socket.

use banded_svd::client::{Client, LocalClient, ReductionRequest, RemoteClient};
use banded_svd::config::{
    BackendKind, BatchConfig, PackingPolicy, ServiceConfig, ShardRouting, TuneParams,
};
use banded_svd::loadgen;
use banded_svd::scalar::ScalarKind;
use banded_svd::service::{Server, ServiceStats};
use banded_svd::util::json::Json;
use banded_svd::util::prop::{check, Config};
use std::time::Duration;

fn params() -> TuneParams {
    TuneParams { tpb: 32, tw: 4, max_blocks: 24 }
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        params: params(),
        batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
        backend: BackendKind::Threadpool,
        threads: 2,
        window: Duration::from_millis(2),
        queue_cap: 64,
        backlog_cap_s: 1e9,
        cache_cap: 32,
        arch: "H100",
        workers: 1,
        routing: ShardRouting::LeastLoaded,
        quota_pending_cap: 0,
        vectors_cap_n: banded_svd::config::DEFAULT_VECTORS_CAP_N,
    }
}

/// Render the service's counters the way the `stats` verb does — exactly
/// the keys [`loadgen::build_report`]'s reconciliation reads.
fn server_counters(stats: &ServiceStats) -> Json {
    Json::obj()
        .set("jobs_submitted", stats.jobs_submitted as i64)
        .set("jobs_rejected", stats.jobs_rejected as i64)
        .set("jobs_completed", stats.jobs_completed as i64)
        .set("jobs_failed", stats.jobs_failed as i64)
        .set("queue_depth", stats.queue_depth as i64)
}

#[derive(Debug)]
struct PlanCase {
    spec: &'static str,
    seed: u64,
    duration_ms: u64,
}

#[test]
fn prop_plans_are_byte_identical_per_seed_for_every_process() {
    // One spec per arrival family; rates high enough that even the
    // shortest generated horizon carries arrivals.
    const SPECS: [&str; 4] =
        ["constant:80", "poisson:120", "bursty:20:300:0.5:0.3", "ramp:40:160"];
    let cfg = Config { cases: 48, ..Config::default() };
    check(
        "loadgen-plan-determinism",
        &cfg,
        |rng| PlanCase {
            spec: SPECS[rng.below(SPECS.len())],
            seed: rng.next_u64(),
            duration_ms: rng.range_inclusive(200, 1200) as u64,
        },
        |case| {
            let process = loadgen::ArrivalProcess::parse(case.spec)?;
            let mix = loadgen::WorkloadMix::resolve("smoke")?;
            let duration = Duration::from_millis(case.duration_ms);
            let a = loadgen::plan(&process, &mix, case.seed, duration);
            let b = loadgen::plan(&process, &mix, case.seed, duration);
            let lines = loadgen::plan_lines(&a, &mix);
            if a.is_empty() {
                return Err("plan rendered no arrivals".into());
            }
            if lines != loadgen::plan_lines(&b, &mix) {
                return Err("same seed produced different plans".into());
            }
            // A different seed must change the stream — for the
            // clock-driven processes the arrival instants repeat, but
            // class picks and payload seeds come from the seeded streams.
            let c = loadgen::plan(&process, &mix, case.seed ^ 1, duration);
            if loadgen::plan_lines(&c, &mix) == lines {
                return Err("changing the seed left the plan identical".into());
            }
            Ok(())
        },
    );
}

#[test]
fn queued_run_reconciles_exactly_with_the_service_counters() {
    let client = LocalClient::queued(service_cfg()).expect("start queued client");
    let mix = loadgen::WorkloadMix::parse(
        "name=small,weight=3,n=32,bw=4;name=medium,n=48,bw=6,prec=fp32",
    )
    .expect("mix spec");
    let process = loadgen::ArrivalProcess::Constant { rate_hz: 60.0 };
    let opts = loadgen::RunOptions {
        seed: 11,
        duration: Duration::from_millis(500),
        ..Default::default()
    };
    let clients: Vec<&(dyn Client + Sync)> =
        (0..2).map(|_| &client as &(dyn Client + Sync)).collect();
    let output = loadgen::run(&clients, &mix, &process, &opts);
    let planned = loadgen::plan(&process, &mix, opts.seed, opts.duration);
    assert_eq!(output.records.len(), planned.len(), "open loop must fire every arrival");

    let stats = client.service().expect("queued client embeds a service").stats();
    let report = loadgen::build_report(&loadgen::ReportInputs {
        mix: &mix,
        process: &process,
        opts: &opts,
        output: &output,
        submitters: clients.len(),
        target: "local:queued",
        client_stats: Some(client.stats()),
        server_stats: Some(server_counters(&stats)),
        profile: None,
    });
    // Uncontended: the whole offered load completes…
    let tally = report.get("tally").expect("tally");
    let completed = tally.get("completed").and_then(Json::as_i64);
    assert_eq!(completed, Some(planned.len() as i64), "{}", tally.render());
    // …and every cross-check against the service's counters holds.
    let rec = report.get("reconciliation").expect("reconciliation");
    assert_eq!(rec.get("checked").and_then(Json::as_bool), Some(true));
    assert_eq!(rec.get("ok").and_then(Json::as_bool), Some(true), "{}", rec.render());
    let client_stats = report.get("client_stats").expect("client_stats");
    assert_eq!(
        client_stats.get("submitted").and_then(Json::as_i64),
        Some(planned.len() as i64),
        "{}",
        client_stats.render()
    );
}

#[test]
fn overload_sheds_only_retryable_kinds_and_still_reconciles() {
    // Capacity is queue_cap + one in-flight flush; eight submitters
    // firing an already-late schedule keep more requests outstanding
    // than that, so admission control must shed.
    let cfg = ServiceConfig {
        threads: 1,
        queue_cap: 2,
        quota_pending_cap: 1,
        window: Duration::from_millis(5),
        batch: BatchConfig { max_coresident: 2, policy: PackingPolicy::RoundRobin },
        ..service_cfg()
    };
    let client = LocalClient::queued(cfg).expect("start queued client");
    // No deadline classes: every failure must be back-pressure, not
    // expiry. The metered class shares one quota identity under a
    // pending cap of 1, so both retryable kinds are reachable.
    let mix = loadgen::WorkloadMix::parse(
        "name=open,weight=3,n=128,bw=8;name=metered,n=128,bw=8,quota=tenant",
    )
    .expect("mix spec");
    let process = loadgen::ArrivalProcess::Constant { rate_hz: 400.0 };
    let opts = loadgen::RunOptions {
        seed: 5,
        duration: Duration::from_millis(500),
        ..Default::default()
    };
    let clients: Vec<&(dyn Client + Sync)> =
        (0..8).map(|_| &client as &(dyn Client + Sync)).collect();
    let output = loadgen::run(&clients, &mix, &process, &opts);
    let planned = loadgen::plan(&process, &mix, opts.seed, opts.duration);
    // run() returning at all is the no-deadlock half of the property;
    // open loop means overload never suppresses an arrival.
    assert_eq!(output.records.len(), planned.len(), "open loop must fire every arrival");

    let mut shed = 0usize;
    for record in &output.records {
        if let loadgen::Disposition::Failed { kind, retryable, message } = &record.disposition {
            assert!(
                matches!(*kind, "overloaded" | "quota-exceeded"),
                "request {} failed with non-back-pressure kind {kind:?}: {message}",
                record.index
            );
            assert!(*retryable, "back-pressure kind {kind:?} must be retryable");
            shed += 1;
        }
    }
    assert!(shed > 0, "a 2x-capacity open-loop load never shed; overload was not reached");

    let stats = client.service().expect("queued client embeds a service").stats();
    let report = loadgen::build_report(&loadgen::ReportInputs {
        mix: &mix,
        process: &process,
        opts: &opts,
        output: &output,
        submitters: clients.len(),
        target: "local:queued",
        client_stats: Some(client.stats()),
        server_stats: Some(server_counters(&stats)),
        profile: None,
    });
    let rec = report.get("reconciliation").expect("reconciliation");
    assert_eq!(rec.get("ok").and_then(Json::as_bool), Some(true), "{}", rec.render());
    // The report's shed breakdown carries only the back-pressure kinds.
    let failures = report.get("tally").and_then(|t| t.get("failures")).expect("failures");
    let by_kind = match failures {
        Json::Obj(pairs) => pairs.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>(),
        other => panic!("failures must be an object: {}", other.render()),
    };
    for kind in by_kind {
        assert!(
            kind == "overloaded" || kind == "quota-exceeded",
            "unexpected failure kind in the report: {kind}"
        );
    }
}

#[test]
fn binary_band_frames_return_bitwise_identical_singular_values() {
    let server = Server::bind(service_cfg(), "127.0.0.1:0").expect("bind ephemeral");
    let addr = server.local_addr().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let inline = RemoteClient::connect(&addr).expect("connect inline client");
    let mut framed = RemoteClient::connect(&addr).expect("connect framed client");
    assert!(framed.proto() >= 4, "server speaks proto {}", framed.proto());
    framed.binary_band_frames(true).expect("enable binary band frames");

    let cases = [
        (1u64, 48usize, 6usize, ScalarKind::F64),
        (2, 36, 5, ScalarKind::F32),
        (3, 56, 7, ScalarKind::F64),
    ];
    for (seed, n, bw, kind) in cases {
        let a = inline.submit_wait(ReductionRequest::new().random(n, bw, kind, seed)).unwrap();
        let b = framed.submit_wait(ReductionRequest::new().random(n, bw, kind, seed)).unwrap();
        let (want, got) = (&a.problems[0].sv, &b.problems[0].sv);
        assert_eq!(want.len(), got.len(), "n={n} bw={bw}: σ count");
        for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
            assert_eq!(
                w.to_bits(),
                g.to_bits(),
                "n={n} bw={bw}: σ[{i}] {w} (inline) vs {g} (framed)"
            );
        }
    }

    framed.shutdown().expect("shutdown through the protocol");
    server_thread.join().expect("server thread").expect("clean shutdown");
}
