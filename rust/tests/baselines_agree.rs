//! The CPU baselines (gbbrd-style, SLATE-style, PLASMA-style) must agree
//! with the tiled GPU-style algorithm on the singular values — they are
//! alternative schedules over the same transform family.

use banded_svd::banded::storage::Banded;
use banded_svd::baselines::{gbbrd_reduce, plasma_like_reduce, slate_like_reduce};
use banded_svd::bulge::reduce_to_bidiagonal;
use banded_svd::config::TuneParams;
use banded_svd::generate::random_banded;
use banded_svd::pipeline::{bidiagonal_singular_values, relative_sv_error};
use banded_svd::util::rng::Xoshiro256;
use banded_svd::util::threadpool::ThreadPool;

fn sv_of(a: &Banded<f64>) -> Vec<f64> {
    let (d, e) = a.bidiagonal();
    bidiagonal_singular_values(&d, &e)
}

#[test]
fn all_reducers_produce_the_same_singular_values() {
    let pool = ThreadPool::new(4);
    let mut rng = Xoshiro256::seed_from_u64(200);
    let (n, bw) = (72usize, 6usize);
    let base = random_banded::<f64>(n, bw, bw - 1, &mut rng);
    let dense = base.to_dense();

    // Tiled (ours).
    let params = TuneParams { tpb: 32, tw: 3, max_blocks: 192 };
    let mut ours = Banded::from_dense(&dense, n, bw, 3);
    reduce_to_bidiagonal(&mut ours, bw, &params);
    let sv_ours = sv_of(&ours);

    // gbbrd (tw = 1 peeling).
    let mut g = Banded::from_dense(&dense, n, bw, 1);
    gbbrd_reduce(&mut g, bw);
    let sv_g = sv_of(&g);

    // SLATE-style (whole bandwidth, sweep-major).
    let mut s = Banded::from_dense(&dense, n, bw, bw - 1);
    slate_like_reduce(&mut s, bw);
    let sv_s = sv_of(&s);

    // PLASMA-style (multicore, task-coalesced).
    let mut p = Banded::from_dense(&dense, n, bw, bw - 1);
    plasma_like_reduce(&mut p, bw, &pool, 2);
    let sv_p = sv_of(&p);

    for (name, sv) in [("gbbrd", &sv_g), ("slate", &sv_s), ("plasma", &sv_p)] {
        let err = relative_sv_error(sv, &sv_ours);
        assert!(err < 1e-10, "{name} vs tiled: err {err}");
    }
}

#[test]
fn plasma_grouping_does_not_change_results() {
    let pool = ThreadPool::new(4);
    let mut rng = Xoshiro256::seed_from_u64(201);
    let (n, bw) = (64usize, 5usize);
    let base = random_banded::<f64>(n, bw, bw - 1, &mut rng);
    let mut reference: Option<Banded<f64>> = None;
    for grouping in [1usize, 2, 3, 8] {
        let mut a = base.clone();
        plasma_like_reduce(&mut a, bw, &pool, grouping);
        match &reference {
            None => reference = Some(a),
            Some(r) => assert_eq!(&a, r, "grouping={grouping}"),
        }
    }
}

#[test]
fn baselines_handle_trivial_bandwidth() {
    let mut rng = Xoshiro256::seed_from_u64(202);
    let pool = ThreadPool::new(2);
    let mut a = random_banded::<f64>(24, 1, 1, &mut rng);
    let before = a.clone();
    slate_like_reduce(&mut a, 1);
    plasma_like_reduce(&mut a, 1, &pool, 1);
    assert_eq!(a, before, "bidiagonal input must be untouched");
}
