//! The unified client's core contract, property-tested end to end:
//! [`LocalClient`] (direct, in-process), [`RemoteClient`] (JSON-lines
//! wire to a loopback `serve` endpoint), and [`ShardedClient`] (a fleet
//! of such endpoints with routing and failover) are **interchangeable**
//! — for the same [`ReductionRequest`] stream they return
//! bitwise-identical singular values, the same per-problem launch
//! accounting, and reconciled job stats (client-side counters agree
//! with each other and with the server's own `stats` view). The sharded
//! contract holds even when an endpoint is killed mid-stream: failover
//! absorbs the death without a single caller-visible failure.
//!
//! Runs over every registry backend that works in a bare checkout
//! (artifact-dependent backends skip loudly, like `pjrt_roundtrip.rs`).
//! Deterministic: seeded generator specs materialize the same band
//! values on both sides (`random_banded` values depend only on
//! `(n, bw, seed)`), so local and remote reduce the *same* matrices.

use banded_svd::backend::for_kind;
use banded_svd::client::{
    Client, ClientStats, LocalClient, ReductionRequest, RemoteClient, RouteStrategy, ShardedClient,
};
use banded_svd::config::{
    BackendKind, BatchConfig, PackingPolicy, ServiceConfig, ShardRouting, TuneParams,
};
use banded_svd::scalar::ScalarKind;
use banded_svd::service::Server;
use banded_svd::util::json::Json;
use banded_svd::util::prop::{check, Config};
use banded_svd::util::rng::Xoshiro256;
use std::time::Duration;

fn params() -> TuneParams {
    TuneParams { tpb: 32, tw: 4, max_blocks: 24 }
}

fn service_cfg(backend: BackendKind) -> ServiceConfig {
    ServiceConfig {
        params: params(),
        batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
        backend,
        threads: 2,
        window: Duration::from_millis(2),
        queue_cap: 256,
        backlog_cap_s: 1e9,
        cache_cap: 32,
        arch: "H100",
        workers: 1,
        routing: ShardRouting::LeastLoaded,
        quota_pending_cap: 0,
        vectors_cap_n: banded_svd::config::DEFAULT_VECTORS_CAP_N,
    }
}

/// Backend kinds that can execute in a bare checkout.
fn artifact_free_kinds() -> Vec<BackendKind> {
    BackendKind::ALL
        .into_iter()
        .filter(|&kind| match for_kind(kind, 1) {
            Ok(backend) => {
                if backend.requires_artifacts() {
                    eprintln!("SKIP client equivalence for {kind:?}: requires compiled artifacts");
                    false
                } else {
                    true
                }
            }
            // pjrt-fused has no plan-executor form by design.
            Err(_) => false,
        })
        .collect()
}

/// One generated request: problem specs plus priority. Specs are seeded,
/// so rebuilding the request for each client yields identical payloads.
#[derive(Debug, Clone)]
struct RequestSpec {
    problems: Vec<(usize, usize, ScalarKind, u64)>,
    priority: u8,
    /// Request dense U/Vᵀ singular-vector panels — the equivalence
    /// contract covers them bitwise like σ.
    vectors: bool,
}

impl RequestSpec {
    fn build(&self) -> ReductionRequest {
        let mut request =
            ReductionRequest::new().priority(self.priority).with_vectors(self.vectors);
        for &(n, bw, kind, seed) in &self.problems {
            request = request.random(n, bw, kind, seed);
        }
        request
    }
}

#[derive(Debug)]
struct StreamCase {
    requests: Vec<RequestSpec>,
}

fn gen_case(rng: &mut Xoshiro256, case_seed: u64) -> StreamCase {
    let kinds = [ScalarKind::F64, ScalarKind::F32, ScalarKind::F16];
    let requests = (0..rng.range_inclusive(1, 3))
        .map(|r| RequestSpec {
            problems: (0..rng.range_inclusive(1, 3))
                .map(|p| {
                    let bw = rng.range_inclusive(2, 7);
                    let n = rng.range_inclusive(3 * bw.max(4), 56);
                    let kind = kinds[rng.below(kinds.len())];
                    (n, bw, kind, case_seed.wrapping_mul(1000) + (r * 10 + p) as u64)
                })
                .collect(),
            priority: rng.below(3) as u8,
            vectors: rng.below(2) == 1,
        })
        .collect();
    StreamCase { requests }
}

fn check_outcomes_match(
    local: &banded_svd::client::ReductionOutcome,
    remote: &banded_svd::client::ReductionOutcome,
    context: &str,
) -> Result<(), String> {
    if local.problems.len() != remote.problems.len() {
        return Err(format!(
            "{context}: {} local vs {} remote problems",
            local.problems.len(),
            remote.problems.len()
        ));
    }
    for (i, (l, r)) in local.problems.iter().zip(remote.problems.iter()).enumerate() {
        if (l.n, l.bw, l.precision) != (r.n, r.bw, r.precision) {
            return Err(format!(
                "{context} problem {i}: shape mismatch ({},{},{}) vs ({},{},{})",
                l.n, l.bw, l.precision, r.n, r.bw, r.precision
            ));
        }
        if l.sv.len() != r.sv.len() {
            return Err(format!("{context} problem {i}: sv length mismatch"));
        }
        for (j, (a, b)) in l.sv.iter().zip(r.sv.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "{context} problem {i}: σ[{j}] differs bitwise: {a} vs {b}"
                ));
            }
        }
        // Per-problem launch accounting is batch-composition-independent
        // (the merge preserves per-problem order), so the summary fields
        // must agree exactly across local merged execution and remote
        // served execution.
        let lm = &l.metrics;
        let rm = &r.metrics;
        if (lm.launches, lm.tasks, lm.max_parallel, lm.unrolled_launches, lm.bytes)
            != (rm.launches, rm.tasks, rm.max_parallel, rm.unrolled_launches, rm.bytes)
        {
            return Err(format!(
                "{context} problem {i}: metrics mismatch {lm:?} vs {rm:?}"
            ));
        }
        // Singular-vector panels ride the same contract: present on both
        // sides or neither, and bitwise equal when present.
        match (&l.u, &r.u, &l.vt, &r.vt) {
            (Some(lu), Some(ru), Some(lvt), Some(rvt)) => {
                if lu.data.len() != ru.data.len() || lvt.data.len() != rvt.data.len() {
                    return Err(format!("{context} problem {i}: panel size mismatch"));
                }
                if lu.data.iter().zip(&ru.data).any(|(a, b)| a.to_bits() != b.to_bits())
                    || lvt.data.iter().zip(&rvt.data).any(|(a, b)| a.to_bits() != b.to_bits())
                {
                    return Err(format!("{context} problem {i}: U/Vt panels differ bitwise"));
                }
            }
            (None, None, None, None) => {}
            _ => return Err(format!("{context} problem {i}: panel presence mismatch")),
        }
    }
    Ok(())
}

fn stats_field(stats: &Json, key: &str) -> i64 {
    stats.get(key).and_then(Json::as_i64).unwrap_or(-1)
}

#[test]
fn local_and_remote_clients_are_bitwise_interchangeable() {
    for kind in artifact_free_kinds() {
        let server = Server::bind(service_cfg(kind), "127.0.0.1:0").expect("bind ephemeral");
        let addr = server.local_addr().to_string();
        let server_thread = std::thread::spawn(move || server.run());

        let local = LocalClient::direct(
            params(),
            BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
            kind,
            2,
        )
        .expect("local client");
        let remote = RemoteClient::connect(&addr).expect("remote client");
        assert_eq!(remote.backend(), kind.name(), "handshake records the serving backend");

        let mut case_index = 0u64;
        let cfg = Config { cases: 6, ..Config::default() };
        check(
            "client-equivalence",
            &cfg,
            |rng| {
                case_index += 1;
                gen_case(rng, case_index)
            },
            |case| {
                // Submit the whole stream through the remote client
                // first (handles park their outcomes until waited), then
                // run the identical requests on the local client and
                // compare as the handles resolve.
                let mut remote_handles = Vec::new();
                for spec in &case.requests {
                    remote_handles
                        .push(remote.submit(spec.build()).map_err(|e| e.to_string())?);
                }
                for (spec, handle) in case.requests.iter().zip(remote_handles) {
                    let local_outcome =
                        local.submit_wait(spec.build()).map_err(|e| e.to_string())?;
                    let remote_outcome = remote.wait(handle).map_err(|e| e.to_string())?;
                    check_outcomes_match(
                        &local_outcome,
                        &remote_outcome,
                        &format!("{kind:?} priority {}", spec.priority),
                    )?;
                }
                Ok(())
            },
        );

        // Reconciled job stats: the two clients observed identical
        // traffic, nothing failed, and the server's own accounting agrees
        // with the remote client's.
        let local_stats = local.stats();
        let remote_stats = remote.stats();
        assert_eq!(local_stats, remote_stats, "{kind:?}: client counters diverged");
        assert_eq!(local_stats.jobs_failed, 0, "{kind:?}");
        assert_eq!(local_stats.jobs_completed, local_stats.jobs_submitted, "{kind:?}");
        let server_view = remote.server_stats().expect("server stats");
        assert_eq!(
            stats_field(&server_view, "jobs_completed"),
            remote_stats.jobs_completed as i64,
            "{kind:?}: server accounting diverged: {}",
            server_view.render()
        );
        assert_eq!(stats_field(&server_view, "jobs_failed"), 0, "{kind:?}");

        remote.shutdown().expect("shutdown");
        server_thread.join().expect("server thread").expect("clean shutdown");
    }
}

#[test]
fn single_and_batched_requests_agree_across_f32_and_f64() {
    // The acceptance shape spelled out: one client each way, a single-
    // problem request and a 4-problem mixed-precision batch, bitwise.
    let kind = BackendKind::Sequential;
    let server = Server::bind(service_cfg(kind), "127.0.0.1:0").expect("bind ephemeral");
    let addr = server.local_addr().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let local =
        LocalClient::direct(params(), BatchConfig::default(), kind, 1).expect("local client");
    let remote = RemoteClient::connect(&addr).expect("remote client");

    let single = || ReductionRequest::new().random(48, 6, ScalarKind::F64, 77);
    let batched = || {
        ReductionRequest::new()
            .random(48, 6, ScalarKind::F64, 101)
            .random(36, 5, ScalarKind::F32, 102)
            .random(56, 7, ScalarKind::F64, 103)
            .random(28, 3, ScalarKind::F32, 104)
    };

    for (label, request) in
        [("single", single as fn() -> ReductionRequest), ("batched", batched)]
    {
        let l = local.submit_wait(request()).expect("local");
        let r = remote.submit_wait(request()).expect("remote");
        check_outcomes_match(&l, &r, label).unwrap();
        // Provenance names the surfaces.
        assert_eq!(l.provenance.source.name(), "local-direct");
        assert_eq!(r.provenance.source.name(), "remote");
        assert_eq!(l.provenance.backend, kind.name());
        assert_eq!(r.provenance.backend, kind.name());
    }

    assert_eq!(
        local.stats(),
        ClientStats { jobs_submitted: 5, jobs_completed: 5, jobs_failed: 0 }
    );
    assert_eq!(local.stats(), remote.stats());

    remote.shutdown().expect("shutdown");
    server_thread.join().expect("server thread").expect("clean shutdown");
}

#[test]
fn simd_backend_round_trips_above_the_packed_gate() {
    // The generated stream above stays below the packed-span gate
    // (bw ≤ 7, tw = 4), so it exercises the SIMD backend's scalar
    // in-place path only. This shape (bw + tw = 72 ≥ 48) routes the
    // served reduction through the packed/vector kernels, proving the
    // wire protocol and the vector path compose: local-direct and
    // remote-served `--backend simd` stay bitwise interchangeable.
    let kind = BackendKind::Simd;
    let wide = TuneParams { tpb: 32, tw: 32, max_blocks: 24 };
    let mut cfg = service_cfg(kind);
    cfg.params = wide;
    let server = Server::bind(cfg, "127.0.0.1:0").expect("bind ephemeral");
    let addr = server.local_addr().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let local = LocalClient::direct(
        wide,
        BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
        kind,
        2,
    )
    .expect("local client");
    let remote = RemoteClient::connect(&addr).expect("remote client");
    assert_eq!(remote.backend(), "simd", "handshake reports the stable backend name");

    // Vectors ride along: the packed-path reflector capture must produce
    // the same panels whether the plan executed locally or behind the
    // wire.
    let request = || {
        ReductionRequest::new()
            .random(192, 40, ScalarKind::F64, 7001)
            .random(160, 36, ScalarKind::F32, 7002)
            .with_vectors(true)
    };
    let l = local.submit_wait(request()).expect("local");
    let r = remote.submit_wait(request()).expect("remote");
    check_outcomes_match(&l, &r, "simd above-gate").unwrap();
    assert_eq!(l.provenance.backend, "simd");
    assert_eq!(r.provenance.backend, "simd");

    remote.shutdown().expect("shutdown");
    server_thread.join().expect("server thread").expect("clean shutdown");
}

#[test]
fn sharded_client_matches_local_bitwise_even_when_an_endpoint_dies_mid_stream() {
    let kind = BackendKind::Sequential;
    let server_a = Server::bind(service_cfg(kind), "127.0.0.1:0").expect("bind a");
    let server_b = Server::bind(service_cfg(kind), "127.0.0.1:0").expect("bind b");
    let addr_a = server_a.local_addr().to_string();
    let addr_b = server_b.local_addr().to_string();
    let thread_a = std::thread::spawn(move || server_a.run());
    let mut thread_b = Some(std::thread::spawn(move || server_b.run()));

    let local = LocalClient::direct(
        params(),
        BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
        kind,
        2,
    )
    .expect("local client");
    // Least-loaded routing alternates an idle fleet deterministically
    // (the tie rotation), so the post-kill half of the stream provably
    // starts on the dead endpoint and must fail over to the survivor.
    let sharded =
        ShardedClient::connect(&[addr_a.as_str(), addr_b.as_str()], RouteStrategy::LeastLoaded)
            .expect("sharded client");
    assert_eq!(sharded.endpoints().len(), 2);
    assert_eq!(sharded.healthy(), 2);
    assert_eq!(sharded.strategy(), RouteStrategy::LeastLoaded);

    let specs: Vec<RequestSpec> = (0..10u64)
        .map(|i| RequestSpec {
            problems: vec![(48, 6, ScalarKind::F64, 900 + i), (36, 5, ScalarKind::F32, 950 + i)],
            priority: (i % 3) as u8,
            // Alternate: panel equality must survive failover too.
            vectors: i % 2 == 0,
        })
        .collect();

    for (i, spec) in specs.iter().enumerate() {
        if i == 4 {
            // Kill endpoint B mid-stream over its own control connection;
            // the sharded client must keep answering without the caller
            // seeing a single failure.
            RemoteClient::connect(&addr_b).expect("control connection").shutdown().expect("ack");
            let handle = thread_b.take().expect("endpoint b killed exactly once");
            handle.join().expect("server b thread").expect("clean shutdown");
        }
        let want = local.submit_wait(spec.build()).expect("local");
        let got = sharded.submit_wait(spec.build()).expect("sharded survives the dead endpoint");
        check_outcomes_match(&want, &got, &format!("request {i}")).unwrap();
        assert_eq!(got.provenance.source.name(), "sharded");
        assert_eq!(got.provenance.backend, kind.name());
    }

    // Failover absorbed the death: every submitted job completed, and the
    // fleet's health view shows exactly one live member.
    assert_eq!(
        sharded.stats(),
        ClientStats { jobs_submitted: 20, jobs_completed: 20, jobs_failed: 0 }
    );
    assert_eq!(sharded.healthy(), 1, "the dead endpoint must be marked down");

    // Fleet-wide shutdown: the survivor acknowledges, the dead member is
    // skipped without surfacing an error.
    sharded.shutdown().expect("fleet shutdown");
    thread_a.join().expect("server a thread").expect("clean shutdown");
}

#[test]
fn vectors_against_a_legacy_protocol_server_fail_typed_and_terminal() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;

    // A minimal protocol-2 endpoint: answers the connect handshake the
    // way a pre-vectors server did. A protocol-2 server knows nothing of
    // the `vectors` request field and would silently serve values only —
    // so the client must refuse before anything reaches the socket.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind stub");
    let addr = listener.local_addr().expect("stub addr").to_string();
    let stub = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut writer = stream;
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap_or(0) > 0 {
            let reply = if line.contains("\"ping\"") {
                "{\"ok\":true,\"proto\":2}"
            } else if line.contains("\"stats\"") {
                "{\"ok\":true,\"stats\":{\"backend\":\"sequential\"}}"
            } else {
                break;
            };
            if writeln!(writer, "{reply}").is_err() {
                break;
            }
            line.clear();
        }
    });

    // Protocol 2 is still a first-class citizen for values-only traffic:
    // the handshake succeeds and records the negotiated version.
    let remote = RemoteClient::connect(&addr).expect("protocol 2 is still accepted");
    assert_eq!(remote.proto(), 2);
    assert_eq!(remote.backend(), "sequential");

    // The capability gate trips client-side with the typed, terminal
    // taxonomy — "unavailable" and not retryable, because resubmitting
    // the identical request to this endpoint can never succeed.
    let err = remote
        .submit(ReductionRequest::new().random(32, 4, ScalarKind::F64, 1).with_vectors(true))
        .unwrap_err();
    let job = err.as_job().expect("typed job error, not config/io");
    assert_eq!(job.kind(), "unavailable");
    assert!(!err.is_retryable(), "{err}");
    assert!(err.to_string().contains("protocol 2"), "{err}");
    let stats = remote.stats();
    assert_eq!((stats.jobs_submitted, stats.jobs_completed, stats.jobs_failed), (0, 0, 1));

    drop(remote);
    stub.join().expect("stub thread");
}
