//! The injectable packed-span gate (`BSVD_PACKED_SPAN_MIN`), exercised
//! through its test seam `set_packed_span_min`.
//!
//! The seam mutates process-global state, so this binary holds exactly
//! one `#[test]` — the harness runs each integration-test binary in its
//! own process, which is what makes overriding the gate safe here while
//! every other test (library or integration) only ever observes the
//! default gate.

use banded_svd::backend::{execute_reduction, SequentialBackend, SimdBackend};
use banded_svd::bulge::cycle::{set_packed_span_min, stage_uses_packed};
use banded_svd::bulge::Stage;
use banded_svd::config::TuneParams;
use banded_svd::generate::random_banded;
use banded_svd::simd::{SimdIsa, SimdSpec};
use banded_svd::util::rng::Xoshiro256;

/// The one reduction shape under test: its stages (b = 24, d = 16, span
/// 40) sit *below* the default gate of 48, so each gate override below
/// provably flips which cycle path runs.
const N: usize = 160;
const BW: usize = 24;
const TW: usize = 16;

fn reduce_sequential(label: &str) -> banded_svd::banded::Banded<f64> {
    let params = TuneParams { tpb: 32, tw: TW, max_blocks: 24 };
    let mut rng = Xoshiro256::seed_from_u64(923);
    let mut a = random_banded::<f64>(N, BW, TW, &mut rng);
    let backend = SequentialBackend::new();
    execute_reduction(&backend, &mut a, BW, &params).unwrap_or_else(|e| panic!("{label}: {e}"));
    assert_eq!(a.max_off_band(1), 0.0, "{label}: band not reduced to bidiagonal");
    a
}

#[test]
fn gate_override_redirects_dispatch_without_changing_results() {
    let below = Stage::new(BW, TW); // span 40 < 48
    let above = Stage::new(40, 32); // span 72 ≥ 48

    // Default gate: the classification the whole suite relies on.
    assert!(!stage_uses_packed(&below), "span 40 stays in-place at the default gate");
    assert!(stage_uses_packed(&above), "span 72 is packed at the default gate");

    // Force every stage through the packed-tile workspace.
    set_packed_span_min(Some(0));
    assert!(stage_uses_packed(&below));
    assert!(stage_uses_packed(&above));
    let forced_packed = reduce_sequential("forced packed");

    // Force every stage through the in-place path (a gate no real span
    // reaches — the setter clamps, so even usize::MAX is accepted).
    set_packed_span_min(Some(usize::MAX));
    assert!(!stage_uses_packed(&below));
    assert!(!stage_uses_packed(&above));
    let forced_inplace = reduce_sequential("forced in-place");

    // Restore the default (env-driven) gate.
    set_packed_span_min(None);
    assert!(!stage_uses_packed(&below));
    assert!(stage_uses_packed(&above));
    let default_gate = reduce_sequential("default gate");

    // The gate is a pure dispatch decision: both cycle paths perform the
    // identical reflector arithmetic, so all three runs agree bitwise.
    assert_eq!(forced_packed, forced_inplace, "packed vs in-place cycle paths diverged");
    assert_eq!(forced_packed, default_gate, "default-gate run diverged");

    // The SIMD backend honors the same gate: with the gate forced open
    // its vector kernels run on every stage of this (normally in-place)
    // shape, and the uncontracted lane contract keeps the result bitwise
    // equal to the sequential runs above.
    set_packed_span_min(Some(0));
    let params = TuneParams { tpb: 32, tw: TW, max_blocks: 24 };
    let mut rng = Xoshiro256::seed_from_u64(923);
    let mut a = random_banded::<f64>(N, BW, TW, &mut rng);
    let spec = SimdSpec::with_contract(SimdIsa::Portable, false);
    let backend = SimdBackend::with_spec(spec, 2);
    execute_reduction(&backend, &mut a, BW, &params).expect("simd forced packed");
    set_packed_span_min(None);
    assert_eq!(a, forced_packed, "simd packed path diverged from the sequential oracle");
}
