//! Fig. 3 protocol as an executable assertion set: stage 1 (f64) →
//! stage 2 in reduced precision → stage 3 (f64), relative error of the
//! singular values vs the prescribed spectrum.

use banded_svd::config::TuneParams;
use banded_svd::generate::{dense_with_spectrum, Spectrum};
use banded_svd::pipeline::{relative_sv_error, singular_values_3stage_mixed, SvdOptions};
use banded_svd::scalar::F16;
use banded_svd::util::rng::Xoshiro256;

fn protocol(n: usize, spectrum: Spectrum, seed: u64) -> (f64, f64, f64) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let sigma = spectrum.sample(n, &mut rng);
    let a = dense_with_spectrum(n, &sigma, &mut rng, n.min(48));
    let opts = SvdOptions {
        bandwidth: 16.min(n / 2),
        params: TuneParams { tpb: 32, tw: 8, max_blocks: 192 },
    };
    let (s64, _) = singular_values_3stage_mixed::<f64>(&a, &opts);
    let (s32, _) = singular_values_3stage_mixed::<f32>(&a, &opts);
    let (s16, _) = singular_values_3stage_mixed::<F16>(&a, &opts);
    (
        relative_sv_error(&s64, &sigma),
        relative_sv_error(&s32, &sigma),
        relative_sv_error(&s16, &sigma),
    )
}

#[test]
fn fp64_is_near_machine_epsilon() {
    for spectrum in Spectrum::ALL {
        let (e64, _, _) = protocol(96, spectrum, 1);
        assert!(e64 < 1e-12, "{spectrum:?}: {e64}");
    }
}

#[test]
fn error_ordering_fp64_lt_fp32_lt_fp16() {
    for (i, spectrum) in Spectrum::ALL.into_iter().enumerate() {
        let (e64, e32, e16) = protocol(96, spectrum, 2 + i as u64);
        assert!(e64 < e32, "{spectrum:?}: {e64} !< {e32}");
        assert!(e32 < e16, "{spectrum:?}: {e32} !< {e16}");
    }
}

#[test]
fn fp32_errors_stay_within_paper_regime() {
    // Paper: FP32 shows a predictable, size-dependent increase but stays
    // well within acceptable limits (≪ 1e-3 at these sizes).
    for spectrum in Spectrum::ALL {
        let (_, e32, _) = protocol(128, spectrum, 5);
        assert!(e32 < 1e-4, "{spectrum:?}: fp32 err {e32}");
    }
}

#[test]
fn fp16_remains_usable_for_well_behaved_spectra() {
    // Paper: FP16 retains acceptable accuracy; best on well-behaved
    // (arithmetic) spectra.
    let (_, _, e16) = protocol(96, Spectrum::Arithmetic, 6);
    assert!(e16 < 0.05, "fp16 err {e16}");
}

#[test]
fn error_grows_moderately_with_size() {
    // "only moderate error growth with size": fp32 error at n=144 stays
    // within ~30x of n=48 (loose shape bound, not a tight constant).
    let (_, e_small, _) = protocol(48, Spectrum::Arithmetic, 7);
    let (_, e_large, _) = protocol(144, Spectrum::Arithmetic, 7);
    assert!(
        e_large < e_small * 30.0 + 1e-6,
        "{e_small} -> {e_large}: growth too fast"
    );
}

#[test]
fn bandwidth_increase_does_not_degrade_accuracy() {
    // Paper §V-A: larger bandwidth at fixed tilewidth does not hurt
    // accuracy (the successive-band-reduction claim).
    let n = 96;
    let mut rng = Xoshiro256::seed_from_u64(8);
    let sigma = Spectrum::Arithmetic.sample(n, &mut rng);
    let a = dense_with_spectrum(n, &sigma, &mut rng, 48);
    let mut errs = Vec::new();
    for bw in [8usize, 16, 32] {
        let opts = SvdOptions {
            bandwidth: bw,
            params: TuneParams { tpb: 32, tw: 8, max_blocks: 192 },
        };
        let (s32, _) = singular_values_3stage_mixed::<f32>(&a, &opts);
        errs.push(relative_sv_error(&s32, &sigma));
    }
    let min = errs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = errs.iter().cloned().fold(0.0, f64::max);
    assert!(max < 20.0 * min + 1e-7, "bandwidth sensitivity too strong: {errs:?}");
}
