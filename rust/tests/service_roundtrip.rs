//! The reduction service's contract, end to end over loopback TCP:
//!
//! - **Bitwise fidelity** — a job submitted over the wire (JSON-lines
//!   protocol, concurrent connections, dynamic micro-batching) returns
//!   exactly the singular values a direct
//!   [`banded_singular_values_with`] call produces on the same backend,
//!   for every registry backend that can run without artifacts
//!   (artifact-dependent backends skip with a loud message, like
//!   `pjrt_roundtrip.rs`).
//! - **Admission-order batching** — the batcher's flush order never
//!   violates admission order within a priority class
//!   (property-tested against the queue).
//! - **Cache amortization** — repeated same-shape submissions report a
//!   positive plan-cache hit rate through the `stats` verb.
//!
//! Deterministic by construction: seeded RNG, explicit thread counts,
//! explicit windows generous enough for coarse platform clocks.

use banded_svd::backend::for_kind;
use banded_svd::batch::BatchInput;
use banded_svd::config::{
    BackendKind, BatchConfig, PackingPolicy, ServiceConfig, ShardRouting, TuneParams,
};
use banded_svd::generate::random_banded;
use banded_svd::pipeline::banded_singular_values_with;
use banded_svd::client::wire::submit_request;
use banded_svd::service::{Server, Service};
use banded_svd::util::json::Json;
use banded_svd::util::prop::{check, Config};
use banded_svd::util::rng::Xoshiro256;
use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn params() -> TuneParams {
    TuneParams { tpb: 32, tw: 4, max_blocks: 24 }
}

fn service_cfg(backend: BackendKind) -> ServiceConfig {
    ServiceConfig {
        params: params(),
        batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
        backend,
        threads: 2,
        window: Duration::from_millis(2),
        queue_cap: 64,
        backlog_cap_s: 1e9,
        cache_cap: 32,
        arch: "H100",
        workers: 1,
        routing: ShardRouting::LeastLoaded,
        quota_pending_cap: 0,
        vectors_cap_n: banded_svd::config::DEFAULT_VECTORS_CAP_N,
    }
}

fn connect(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    let stream = TcpStream::connect(addr).expect("connect to loopback server");
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    (reader, stream)
}

fn roundtrip(reader: &mut BufReader<TcpStream>, writer: &mut TcpStream, line: &str) -> Json {
    writeln!(writer, "{line}").expect("send request");
    writer.flush().expect("flush request");
    let mut response = String::new();
    reader.read_line(&mut response).expect("read response");
    Json::parse(response.trim_end()).expect("parse response")
}

fn sv_of(response: &Json) -> Vec<f64> {
    assert_eq!(response.get("ok").and_then(Json::as_bool), Some(true), "{}", response.render());
    response
        .get("sv")
        .and_then(Json::as_array)
        .expect("sv array")
        .iter()
        .map(|v| v.as_f64().expect("numeric singular value"))
        .collect()
}

fn assert_bitwise(got: &[f64], want: &[f64], context: &str) {
    assert_eq!(got.len(), want.len(), "{context}: length");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{context}: σ[{i}] {g} vs {w}");
    }
}

/// One client's precomputed workload: request lines plus the direct
/// pipeline's answer for each.
struct ClientLoad {
    requests: Vec<String>,
    expected: Vec<Vec<f64>>,
}

fn build_load(kind: BackendKind, seed: u64, jobs: usize) -> ClientLoad {
    let backend = for_kind(kind, 2).expect("plan backend");
    let params = params();
    let shapes = [(48usize, 6usize, "fp64"), (36, 5, "fp32"), (56, 7, "fp64"), (28, 3, "fp32")];
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut requests = Vec::with_capacity(jobs);
    let mut expected = Vec::with_capacity(jobs);
    for job in 0..jobs {
        let (n, bw, precision) = shapes[job % shapes.len()];
        let tw = params.effective_tw(bw);
        if precision == "fp64" {
            let a = random_banded::<f64>(n, bw, tw, &mut rng);
            let sv = banded_singular_values_with(backend.as_ref(), &a, bw, &params).unwrap();
            expected.push(sv);
            requests.push(submit_request(&a, bw, 0));
        } else {
            let a = random_banded::<f32>(n, bw, tw, &mut rng);
            let sv = banded_singular_values_with(backend.as_ref(), &a, bw, &params).unwrap();
            expected.push(sv);
            requests.push(submit_request(&a, bw, 0));
        }
    }
    ClientLoad { requests, expected }
}

#[test]
fn served_results_are_bitwise_identical_to_the_direct_pipeline() {
    for kind in BackendKind::ALL {
        let backend = match for_kind(kind, 2) {
            Ok(b) => b,
            // pjrt-fused has no plan-executor form by design.
            Err(_) => continue,
        };
        if backend.requires_artifacts() {
            eprintln!("SKIP service roundtrip for {kind:?}: requires compiled artifacts");
            continue;
        }
        drop(backend);

        let server = Server::bind(service_cfg(kind), "127.0.0.1:0").expect("bind ephemeral");
        let addr = server.local_addr();
        let server_thread = std::thread::spawn(move || server.run());

        // Three concurrent connections × four jobs each: concurrency
        // feeds the micro-batcher, so flushes genuinely merge plans.
        let loads: Vec<ClientLoad> = (0u64..3).map(|c| build_load(kind, 1000 + c, 4)).collect();
        std::thread::scope(|scope| {
            for (c, load) in loads.iter().enumerate() {
                scope.spawn(move || {
                    let (mut reader, mut writer) = connect(addr);
                    for (j, (line, want)) in
                        load.requests.iter().zip(load.expected.iter()).enumerate()
                    {
                        let response = roundtrip(&mut reader, &mut writer, line);
                        let sv = sv_of(&response);
                        assert_bitwise(&sv, want, &format!("{kind:?} client {c} job {j}"));
                    }
                });
            }
        });

        // Shutdown through the protocol; the server must exit cleanly.
        let (mut reader, mut writer) = connect(addr);
        let stats = roundtrip(&mut reader, &mut writer, "{\"verb\":\"stats\"}");
        let body = stats.get("stats").expect("stats body");
        let completed = body.get("jobs_completed").and_then(Json::as_i64).unwrap();
        assert_eq!(completed, 12, "{kind:?}");
        let ack = roundtrip(&mut reader, &mut writer, "{\"verb\":\"shutdown\"}");
        assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true));
        server_thread.join().expect("server thread").expect("clean shutdown");
    }
}

#[test]
fn multi_worker_service_drains_mixed_priorities_with_reconciling_shard_stats() {
    // Two batcher shards, each with its own backend, fed by the router.
    // Mixed-priority traffic from concurrent connections must still come
    // back bitwise identical to the direct pipeline, and the per-shard
    // stats rows exposed through the `stats` verb must reconcile with
    // the aggregate counters.
    let cfg = ServiceConfig { workers: 2, ..service_cfg(BackendKind::Sequential) };
    let server = Server::bind(cfg, "127.0.0.1:0").expect("bind ephemeral");
    let addr = server.local_addr();
    let server_thread = std::thread::spawn(move || server.run());

    let direct = for_kind(BackendKind::Sequential, 2).expect("direct backend");
    let params = params();
    let shapes = [(48usize, 6usize), (36, 5), (56, 7), (28, 3)];
    let mut rng = Xoshiro256::seed_from_u64(77);
    // (request line, expected σ) with priorities cycling 2, 1, 0, …
    let mut jobs: Vec<(String, Vec<f64>)> = Vec::new();
    for job in 0..12usize {
        let (n, bw) = shapes[job % shapes.len()];
        let a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let want = banded_singular_values_with(direct.as_ref(), &a, bw, &params).unwrap();
        jobs.push((submit_request(&a, bw, (job % 3) as u8), want));
    }

    std::thread::scope(|scope| {
        for (c, chunk) in jobs.chunks(4).enumerate() {
            scope.spawn(move || {
                let (mut reader, mut writer) = connect(addr);
                for (j, (line, want)) in chunk.iter().enumerate() {
                    let response = roundtrip(&mut reader, &mut writer, line);
                    let sv = sv_of(&response);
                    assert_bitwise(&sv, want, &format!("sharded client {c} job {j}"));
                }
            });
        }
    });

    let (mut reader, mut writer) = connect(addr);
    let stats = roundtrip(&mut reader, &mut writer, "{\"verb\":\"stats\"}");
    let body = stats.get("stats").expect("stats body");
    assert_eq!(body.get("workers").and_then(Json::as_i64), Some(2), "{}", body.render());
    let shards = body.get("shards").and_then(Json::as_array).expect("shards array");
    assert_eq!(shards.len(), 2, "{}", body.render());
    let aggregate = body.get("jobs_completed").and_then(Json::as_i64).unwrap();
    assert_eq!(aggregate, 12, "{}", body.render());
    let per_shard: i64 = shards
        .iter()
        .map(|s| s.get("jobs_completed").and_then(Json::as_i64).expect("shard jobs_completed"))
        .sum();
    assert_eq!(per_shard, aggregate, "per-shard rows must reconcile: {}", body.render());
    for (i, shard) in shards.iter().enumerate() {
        assert_eq!(shard.get("shard").and_then(Json::as_i64), Some(i as i64));
        assert_eq!(shard.get("jobs_failed").and_then(Json::as_i64), Some(0));
        assert_eq!(shard.get("queue_depth").and_then(Json::as_i64), Some(0));
    }

    let ack = roundtrip(&mut reader, &mut writer, "{\"verb\":\"shutdown\"}");
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true));
    server_thread.join().expect("server thread").expect("clean shutdown");
}

#[test]
fn repeated_shapes_report_cache_hits_through_stats() {
    let server = Server::bind(service_cfg(BackendKind::Sequential), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let server_thread = std::thread::spawn(move || server.run());

    let (mut reader, mut writer) = connect(addr);
    let mut rng = Xoshiro256::seed_from_u64(7);
    for _ in 0..5 {
        // Same (n, bw, precision, params) every time: the plan store must
        // hit after the first lowering.
        let a = random_banded::<f64>(40, 5, 4, &mut rng);
        let response = roundtrip(&mut reader, &mut writer, &submit_request(&a, 5, 0));
        assert_eq!(response.get("ok").and_then(Json::as_bool), Some(true));
    }
    let stats = roundtrip(&mut reader, &mut writer, "{\"verb\":\"stats\"}");
    let cache = stats.get("stats").and_then(|s| s.get("cache")).expect("cache stats");
    let plan_hits = cache.get("plan_hits").and_then(Json::as_i64).unwrap();
    let hit_rate = cache.get("hit_rate").and_then(Json::as_f64).unwrap();
    assert!(plan_hits > 0, "no plan-cache hits after repeated shapes: {}", cache.render());
    assert!(hit_rate > 0.0, "hit rate 0 after repeated shapes: {}", cache.render());

    let ack = roundtrip(&mut reader, &mut writer, "{\"verb\":\"shutdown\"}");
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true));
    server_thread.join().unwrap().unwrap();
}

#[test]
fn full_size_flushes_co_schedule_all_waiting_jobs() {
    // Window far above any scheduler hiccup: the flush can only trigger
    // on size, so all four concurrently submitted jobs must ride one
    // merged plan — and still match the direct pipeline bitwise.
    let cfg = ServiceConfig {
        window: Duration::from_secs(30),
        batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
        ..service_cfg(BackendKind::Sequential)
    };
    let service = Service::start(cfg).unwrap();
    let params = params();
    let shapes = [(48usize, 6usize), (36, 5), (56, 7), (28, 3)];
    let mut rng = Xoshiro256::seed_from_u64(21);
    let mats: Vec<_> = shapes
        .iter()
        .map(|&(n, bw)| random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng))
        .collect();
    let direct = for_kind(BackendKind::Sequential, 1).unwrap();
    let mut expected: Vec<Vec<f64>> = Vec::new();
    for (a, &(_, bw)) in mats.iter().zip(shapes.iter()) {
        expected.push(banded_singular_values_with(direct.as_ref(), a, bw, &params).unwrap());
    }

    let barrier = std::sync::Barrier::new(4);
    std::thread::scope(|scope| {
        for ((a, &(_, bw)), want) in mats.iter().zip(shapes.iter()).zip(expected.iter()) {
            let (service, barrier) = (&service, &barrier);
            scope.spawn(move || {
                barrier.wait();
                let input = BatchInput::from((a.clone(), bw));
                let result = service.submit_wait(input, 0, None).unwrap();
                assert_eq!(result.batch_jobs, 4, "flush did not co-schedule all jobs");
                assert_bitwise(&result.sv, want, &format!("co-scheduled bw={bw}"));
            });
        }
    });
    let stats = service.stats();
    assert_eq!(stats.jobs_completed, 4);
    assert_eq!(stats.batches, 1, "expected one merged flush");
    assert!(stats.avg_batch_jobs > 3.9);
}

#[derive(Debug)]
struct FlushCase {
    /// (priority, pop-after) for each submitted job: after submitting
    /// job `i`, pop a batch of `pop_after[i]` jobs (0 = no pop).
    jobs: Vec<(u8, usize)>,
}

#[test]
fn prop_flush_order_never_violates_admission_order_within_a_class() {
    use banded_svd::service::queue::JobQueue;
    use std::sync::mpsc;

    let cfg = Config { cases: 64, ..Config::default() };
    check(
        "service-flush-order",
        &cfg,
        |rng| {
            let jobs = (0..rng.range_inclusive(3, 24))
                .map(|_| (rng.below(3) as u8, rng.below(4)))
                .collect();
            FlushCase { jobs }
        },
        |case| {
            let queue = JobQueue::new(1024, 1e12);
            let mut rng = Xoshiro256::seed_from_u64(13);
            let mut receivers = Vec::new();
            let mut popped: Vec<(u8, u64)> = Vec::new(); // (priority, id)
            let mut submitted_per_class: Vec<Vec<u64>> = vec![Vec::new(); 3];
            let pop = |queue: &JobQueue, max: usize, popped: &mut Vec<(u8, u64)>| {
                let batch = queue.pop_batch(max);
                // Within one flush, order is (priority, admission seq).
                for pair in batch.windows(2) {
                    let key0 = (pair[0].priority, pair[0].seq);
                    let key1 = (pair[1].priority, pair[1].seq);
                    if key0 >= key1 {
                        return Err(format!("flush out of order: {key0:?} !< {key1:?}"));
                    }
                }
                popped.extend(batch.iter().map(|j| (j.priority, j.id)));
                Ok(())
            };
            for (id, &(priority, pop_after)) in case.jobs.iter().enumerate() {
                let input = BatchInput::from((random_banded::<f64>(24, 3, 2, &mut rng), 3));
                let (tx, rx) = mpsc::channel();
                queue.submit(id as u64, input, priority, None, 0.0, tx).unwrap();
                receivers.push(rx);
                submitted_per_class[priority as usize].push(id as u64);
                if pop_after > 0 {
                    pop(&queue, pop_after, &mut popped)?;
                }
            }
            while queue.depth() > 0 {
                pop(&queue, 2, &mut popped)?;
            }
            // Every job drained exactly once, and within each priority
            // class the drain order is the admission order.
            if popped.len() != case.jobs.len() {
                return Err(format!("drained {} of {} jobs", popped.len(), case.jobs.len()));
            }
            for class in 0u8..3 {
                let drained: Vec<u64> =
                    popped.iter().filter(|(p, _)| *p == class).map(|(_, id)| *id).collect();
                if drained != submitted_per_class[class as usize] {
                    return Err(format!(
                        "class {class}: drained {drained:?}, admitted {:?}",
                        submitted_per_class[class as usize]
                    ));
                }
            }
            Ok(())
        },
    );
}
