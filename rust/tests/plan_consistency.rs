//! The plan IR's central contract, property-tested: for a given
//! `(n, bw, TuneParams)` every backend and the simulator consume the
//! **identical** `LaunchPlan` value — so predicted and executed schedules
//! agree launch by launch (launch count, tasks per launch, algorithmic
//! byte traffic), with no independent schedule re-derivation anywhere —
//! and every registered backend that can run without artifacts produces
//! **bitwise-identical** storage to the sequential reference.

use banded_svd::backend::{execute_reduction, for_kind, SequentialBackend, SimdBackend};
use banded_svd::config::{BackendKind, TuneParams};
use banded_svd::coordinator::Coordinator;
use banded_svd::generate::random_banded;
use banded_svd::pipeline::banded_svd_vectors_with;
use banded_svd::plan::LaunchPlan;
use banded_svd::scalar::Scalar;
use banded_svd::simd::{detect_isa, SimdIsa, SimdSpec};
use banded_svd::simulator::{hw, simulate_plan, simulate_reduction};
use banded_svd::util::prop::{check, Config};
use banded_svd::util::rng::Xoshiro256;

#[derive(Debug)]
struct Case {
    n: usize,
    bw: usize,
    tw: usize,
    max_blocks: usize,
    tpb: usize,
    seed: u64,
}

fn gen_case(rng: &mut Xoshiro256) -> Case {
    let bw = rng.range_inclusive(2, 12);
    Case {
        n: rng.range_inclusive(bw + 4, 96),
        bw,
        tw: rng.range_inclusive(1, 8),
        max_blocks: rng.range_inclusive(1, 48),
        tpb: [8, 16, 32][rng.below(3)],
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_simulator_and_executor_consume_the_identical_plan() {
    let cfg = Config { cases: 48, ..Config::default() };
    check("simulated-plan-equals-executed-plan", &cfg, gen_case, |case| {
        let params = TuneParams { tpb: case.tpb, tw: case.tw, max_blocks: case.max_blocks };
        let coord = Coordinator::new(params, 4);

        // The value the executor runs and the value the simulator costs
        // must be the same lowering — compared as whole IR values.
        let executed = coord.launch_plan(case.n, case.bw);
        let costed = LaunchPlan::for_problem(case.n, case.bw, &params);
        if executed != costed {
            return Err("coordinator and simulator lowered different plans".into());
        }

        // Execute (both native backends) and simulate.
        let es = std::mem::size_of::<f64>();
        let mut rng = Xoshiro256::seed_from_u64(case.seed);
        let mut a = random_banded::<f64>(case.n, case.bw, params.effective_tw(case.bw), &mut rng);
        let mut b = a.clone();
        let run = coord
            .reduce_native(&mut a, case.bw, BackendKind::Threadpool)
            .map_err(|e| e.to_string())?;
        let seq = coord
            .reduce_native(&mut b, case.bw, BackendKind::Sequential)
            .map_err(|e| e.to_string())?;
        let sim = simulate_plan(&hw::H100, es, &costed, params.tpb);

        // Launch count.
        let launches = costed.num_launches();
        if run.metrics.launches != launches || sim.launches != launches {
            return Err(format!(
                "launch counts diverge: executed {} / simulated {} / plan {launches}",
                run.metrics.launches, sim.launches
            ));
        }
        // Tasks per launch, launch by launch, across executor, sequential
        // oracle, simulator, and the plan itself.
        for li in 0..costed.num_launches() {
            let want = costed.launch_tasks(li) as u32;
            if run.metrics.per_launch[li] != want
                || seq.metrics.per_launch[li] != want
                || sim.per_launch[li] != want
            {
                return Err(format!(
                    "launch {li}: tasks diverge (parallel {}, sequential {}, simulated {}, plan {want})",
                    run.metrics.per_launch[li], seq.metrics.per_launch[li], sim.per_launch[li]
                ));
            }
        }
        // Per-launch byte traffic (aggregated — both sides accumulate the
        // same plan-derived quantity per launch).
        let plan_bytes: u64 = (0..costed.num_launches())
            .map(|li| costed.launch_bytes(li, es))
            .sum();
        if run.metrics.bytes != plan_bytes || sim.algo_bytes != plan_bytes {
            return Err(format!(
                "byte traffic diverges: executed {} / simulated {} / plan {plan_bytes}",
                run.metrics.bytes, sim.algo_bytes
            ));
        }
        // Totals.
        if run.metrics.tasks != costed.total_tasks() || sim.tasks != costed.total_tasks() {
            return Err("total task counts diverge".into());
        }
        // And the reduction actually completed.
        if run.residual_off_band != 0.0 {
            return Err("parallel run left off-bidiagonal residual".into());
        }
        Ok(())
    });
}

#[test]
fn prop_every_registered_backend_matches_the_sequential_reference() {
    // The backend contract (docs/backends.md): any registered backend
    // that can run without pre-compiled artifacts must produce
    // bitwise-identical storage to the sequential reference on the same
    // plan, with identical per-launch metrics. PJRT variants (artifact-
    // dependent) are covered by rust/tests/pjrt_roundtrip.rs instead.
    let cfg = Config { cases: 24, ..Config::default() };
    check("backend-equivalence", &cfg, gen_case, |case| {
        let params = TuneParams { tpb: case.tpb, tw: case.tw, max_blocks: case.max_blocks };
        let mut rng = Xoshiro256::seed_from_u64(case.seed);
        let base = random_banded::<f64>(case.n, case.bw, params.effective_tw(case.bw), &mut rng);

        let mut reference = base.clone();
        let (plan, ref_exec) =
            execute_reduction(&SequentialBackend::new(), &mut reference, case.bw, &params)
                .map_err(|e| e.to_string())?;
        if reference.max_off_band(1) != 0.0 {
            return Err("sequential reference did not reach bidiagonal form".into());
        }

        let mut compared = 0;
        for kind in BackendKind::ALL {
            let backend = match for_kind(kind, 3) {
                Ok(b) => b,
                // pjrt-fused has no plan-executor form by design.
                Err(_) => continue,
            };
            if backend.requires_artifacts() {
                continue;
            }
            let mut work = base.clone();
            let (_, exec) = execute_reduction(backend.as_ref(), &mut work, case.bw, &params)
                .map_err(|e| e.to_string())?;
            if work != reference {
                return Err(format!("{kind:?}: storage differs from the sequential reference"));
            }
            if exec.per_problem[0].per_launch != ref_exec.per_problem[0].per_launch {
                return Err(format!("{kind:?}: per-launch metrics differ"));
            }
            if exec.per_problem[0].bytes != ref_exec.per_problem[0].bytes {
                return Err(format!("{kind:?}: byte accounting differs"));
            }
            if exec.aggregate.launches != plan.num_launches() {
                return Err(format!(
                    "{kind:?}: executed {} launches, plan has {}",
                    exec.aggregate.launches,
                    plan.num_launches()
                ));
            }
            compared += 1;
        }
        if compared < 2 {
            return Err(format!("only {compared} native backends registered; expected ≥ 2"));
        }
        Ok(())
    });
}

/// The specs the SIMD equivalence tests sweep: forced-scalar (the
/// `BSVD_SIMD=off` configuration), the portable lane path, and whatever
/// ISA this host detects (AVX2+FMA on x86-64, NEON on aarch64 — equal to
/// portable where detection fails).
fn simd_specs(contract: bool) -> Vec<SimdSpec> {
    vec![
        SimdSpec::scalar(),
        SimdSpec::with_contract(SimdIsa::Portable, contract),
        SimdSpec::with_contract(detect_isa().unwrap_or(SimdIsa::Portable), contract),
    ]
}

/// Shapes straddling the packed gate (`b + d ≥ 48`): the wide ones route
/// every stage through the packed (vectorizable) kernels, the narrow one
/// stays entirely on the in-place scalar path.
const SIMD_SHAPES: [(usize, usize, usize); 3] = [(192, 40, 32), (280, 56, 16), (96, 10, 4)];

fn simd_matches_sequential_bitwise<T: Scalar>(seed: u64)
where
    banded_svd::banded::Banded<T>: banded_svd::backend::AsBandStorageMut,
{
    for &(n, bw, tw) in &SIMD_SHAPES {
        let params = TuneParams { tpb: 32, tw, max_blocks: 24 };
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let base = random_banded::<T>(n, bw, params.effective_tw(bw), &mut rng);

        let mut reference = base.clone();
        let (plan, ref_exec) =
            execute_reduction(&SequentialBackend::new(), &mut reference, bw, &params).unwrap();
        assert_eq!(reference.max_off_band(1), 0.0, "reference incomplete (n={n}, bw={bw})");

        for spec in simd_specs(false) {
            let mut work = base.clone();
            let backend = SimdBackend::with_spec(spec, 3);
            let (_, exec) = execute_reduction(&backend, &mut work, bw, &params).unwrap();
            assert_eq!(work, reference, "n={n} bw={bw} {spec:?}");
            assert_eq!(
                exec.per_problem[0].per_launch, ref_exec.per_problem[0].per_launch,
                "n={n} bw={bw} {spec:?}"
            );
            assert_eq!(exec.aggregate.launches, plan.num_launches());
        }
    }
}

#[test]
fn simd_backend_is_bitwise_equal_to_sequential_in_f64() {
    // The tentpole equivalence bar: with contraction off, the SIMD
    // backend is bitwise-identical to the sequential oracle across
    // shapes above and below the packed gate — on every ISA arm,
    // including the forced-scalar fallback (`BSVD_SIMD=off`).
    simd_matches_sequential_bitwise::<f64>(11);
}

#[test]
fn simd_backend_is_bitwise_equal_to_sequential_in_f32() {
    simd_matches_sequential_bitwise::<f32>(13);
}

#[test]
fn singular_vector_panels_are_bitwise_equal_across_backends_and_simd_specs() {
    // The vectors extension of the backend contract: the reflector log a
    // backend fills — and therefore the replayed U/Vᵀ panels and the
    // Demmel–Kahan singular values — must be bitwise what the sequential
    // oracle records. Swept across the same shapes as the storage tests,
    // straddling the packed gate, so the `BSVD_SIMD=force` CI leg drives
    // the packed lane kernels' capture path and `BSVD_SIMD=off` the
    // forced-scalar one (`SimdSpec::scalar()` is that configuration's
    // explicit-spec equivalent).
    use banded_svd::backend::ThreadpoolBackend;

    for &(n, bw, tw) in &SIMD_SHAPES {
        let params = TuneParams { tpb: 32, tw, max_blocks: 24 };
        let mut rng = Xoshiro256::seed_from_u64(19);
        let base = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);

        let oracle =
            banded_svd_vectors_with(&SequentialBackend::new(), &base, bw, &params).unwrap();
        assert!(oracle.sv.windows(2).all(|w| w[0] >= w[1]), "n={n} bw={bw}: not descending");

        let tp = banded_svd_vectors_with(&ThreadpoolBackend::new(3), &base, bw, &params).unwrap();
        assert_eq!(oracle.sv, tp.sv, "threadpool sv n={n} bw={bw}");
        assert_eq!(oracle.u, tp.u, "threadpool U n={n} bw={bw}");
        assert_eq!(oracle.vt, tp.vt, "threadpool Vᵀ n={n} bw={bw}");

        for spec in simd_specs(false) {
            let backend = SimdBackend::with_spec(spec, 3);
            let simd = banded_svd_vectors_with(&backend, &base, bw, &params).unwrap();
            assert_eq!(oracle.sv, simd.sv, "{spec:?} sv n={n} bw={bw}");
            assert_eq!(oracle.u, simd.u, "{spec:?} U n={n} bw={bw}");
            assert_eq!(oracle.vt, simd.vt, "{spec:?} Vᵀ n={n} bw={bw}");
        }
    }
}

#[test]
fn contracted_simd_reductions_stay_within_ulp_scale_tolerance() {
    // `BSVD_SIMD_CONTRACT=1` trades bitwise identity for lane-parallel
    // reductions: results must stay a tiny multiple of machine epsilon
    // from the oracle (relative to the matrix norm) and remain exactly
    // bidiagonal, deterministically on every vector ISA.
    let (n, bw, tw) = (192usize, 40usize, 32usize);
    let params = TuneParams { tpb: 32, tw, max_blocks: 24 };
    let mut rng = Xoshiro256::seed_from_u64(17);
    let base = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);

    let mut reference = base.clone();
    execute_reduction(&SequentialBackend::new(), &mut reference, bw, &params).unwrap();
    let scale = reference.fro_norm();

    let mut portable_result = None;
    for spec in simd_specs(true) {
        if !spec.is_vector() {
            continue;
        }
        let mut work = base.clone();
        let backend = SimdBackend::with_spec(spec, 2);
        execute_reduction(&backend, &mut work, bw, &params).unwrap();
        assert_eq!(work.max_off_band(1), 0.0, "{spec:?}: not bidiagonal");
        let worst = work
            .data()
            .iter()
            .zip(reference.data().iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(worst <= 1e-10 * scale, "{spec:?}: drift {worst:e} vs scale {scale:e}");
        // Contracted reductions use a fixed fold tree, so every vector
        // ISA produces the same bits — host-independent determinism.
        match &portable_result {
            None => portable_result = Some(work),
            Some(first) => assert_eq!(&work, first, "{spec:?}: contract result is ISA-dependent"),
        }
    }
}

#[test]
fn simulate_reduction_is_plan_costing() {
    // The public entry point must be exactly `lower + simulate_plan` —
    // the acceptance criterion that no simulator-private schedule exists.
    for (n, bw, tw, mb) in [(96usize, 8usize, 4usize, 16usize), (64, 5, 2, 7), (200, 16, 8, 48)] {
        let params = TuneParams { tpb: 32, tw, max_blocks: mb };
        let plan = LaunchPlan::for_problem(n, bw, &params);
        let a = simulate_reduction(&hw::H100, 4, n, bw, &params);
        let b = simulate_plan(&hw::H100, 4, &plan, params.tpb);
        assert_eq!(a.launches, b.launches);
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.per_launch, b.per_launch);
        assert_eq!(a.algo_bytes, b.algo_bytes);
        assert!((a.seconds - b.seconds).abs() <= 1e-12 * b.seconds.max(1.0));
    }
}
