//! Property tests (library prop framework) over the coordinator-level
//! invariants: schedule coverage/disjointness, reduction correctness
//! under random shapes, and parallel determinism.

use banded_svd::banded::storage::Banded;
use banded_svd::bulge::schedule::{stage_plan, Stage};
use banded_svd::bulge::{reduce_to_bidiagonal, reduce_to_bidiagonal_parallel};
use banded_svd::config::TuneParams;
use banded_svd::generate::random_banded;
use banded_svd::util::prop::{quickcheck, Config};
use banded_svd::util::rng::Xoshiro256;
use banded_svd::util::threadpool::ThreadPool;

#[test]
fn prop_stage_plan_always_terminates_at_bidiagonal() {
    quickcheck(
        "stage-plan-terminates",
        |rng| (rng.range_inclusive(2, 300), rng.range_inclusive(1, 128)),
        |&(bw, tw)| {
            let plan = stage_plan(bw, tw);
            let mut b = bw;
            for s in &plan {
                if s.b != b || s.d == 0 || s.d > s.b - 1 {
                    return Err(format!("bad stage {s:?} at b={b}"));
                }
                b -= s.d;
            }
            if b != 1 {
                return Err(format!("plan ends at bandwidth {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_schedule_covers_every_task_once() {
    quickcheck(
        "schedule-coverage",
        |rng| {
            let b = rng.range_inclusive(2, 12);
            let d = rng.range_inclusive(1, b - 1);
            let n = rng.range_inclusive(b + 2, 140);
            (n, b, d)
        },
        |&(n, b, d)| {
            let s = Stage::new(b, d);
            let mut seen = std::collections::HashSet::new();
            for t in 0..s.total_launches(n) {
                for task in s.tasks_at(n, t) {
                    if !seen.insert((task.sweep, task.cycle)) {
                        return Err(format!("duplicate task {task:?}"));
                    }
                }
            }
            let expect: usize = (0..s.num_sweeps(n)).map(|k| s.cmax(n, k) + 1).sum();
            if seen.len() != expect {
                return Err(format!("covered {} of {expect} tasks", seen.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simultaneous_tasks_are_element_disjoint() {
    quickcheck(
        "schedule-disjointness",
        |rng| {
            let b = rng.range_inclusive(2, 10);
            let d = rng.range_inclusive(1, b - 1);
            let n = rng.range_inclusive(b + 2, 120);
            (n, b, d)
        },
        |&(n, b, d)| {
            let s = Stage::new(b, d);
            for t in 0..s.total_launches(n) {
                let tasks = s.tasks_at(n, t);
                for (i, a) in tasks.iter().enumerate() {
                    for bb in tasks.iter().skip(i + 1) {
                        for ra in s.accesses(a, n) {
                            for rb in s.accesses(bb, n) {
                                if ra.intersects(&rb) {
                                    return Err(format!("t={t}: {a:?} overlaps {bb:?}"));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reduction_is_bidiagonal_and_norm_preserving() {
    let cfg = Config { cases: 24, ..Config::default() };
    banded_svd::util::prop::check(
        "reduction-invariants",
        &cfg,
        |rng| {
            let bw = rng.range_inclusive(2, 12);
            let tw = rng.range_inclusive(1, bw - 1);
            let n = rng.range_inclusive(bw + 2, 96);
            let seed = rng.next_u64();
            (n, bw, tw, seed)
        },
        |&(n, bw, tw, seed)| {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
            let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
            let norm0 = a.fro_norm();
            reduce_to_bidiagonal(&mut a, bw, &params);
            if a.max_off_band(1) != 0.0 {
                return Err(format!("off-band residue {}", a.max_off_band(1)));
            }
            let drift = (a.fro_norm() - norm0).abs();
            if drift > 1e-9 * norm0.max(1.0) {
                return Err(format!("norm drift {drift}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_equals_sequential_bitwise() {
    let pool = ThreadPool::new(4);
    let cfg = Config { cases: 16, ..Config::default() };
    banded_svd::util::prop::check(
        "parallel-determinism",
        &cfg,
        |rng| {
            let bw = rng.range_inclusive(2, 10);
            let tw = rng.range_inclusive(1, bw - 1);
            let n = rng.range_inclusive(bw + 2, 80);
            let mb = rng.range_inclusive(1, 16);
            let seed = rng.next_u64();
            (n, bw, tw, mb, seed)
        },
        |&(n, bw, tw, mb, seed)| {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let params = TuneParams { tpb: 32, tw, max_blocks: mb };
            let a0: Banded<f64> = random_banded(n, bw, params.effective_tw(bw), &mut rng);
            let mut a1 = a0.clone();
            let mut a2 = a0;
            reduce_to_bidiagonal(&mut a1, bw, &params);
            reduce_to_bidiagonal_parallel(&mut a2, bw, &params, &pool);
            if a1 != a2 {
                return Err("parallel result differs from sequential".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reduction_works_in_all_precisions() {
    use banded_svd::scalar::{Scalar, F16};
    fn run<T: Scalar>(n: usize, bw: usize, tw: usize, seed: u64) -> Result<(), String> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
        let mut a = random_banded::<T>(n, bw, params.effective_tw(bw), &mut rng);
        reduce_to_bidiagonal(&mut a, bw, &params);
        let tol = match T::NAME {
            "fp16" => 1e-1,
            "fp32" => 1e-3,
            _ => 1e-10,
        };
        if a.max_off_band(1) > tol {
            return Err(format!("{}: off-band {}", T::NAME, a.max_off_band(1)));
        }
        Ok(())
    }
    let cfg = Config { cases: 10, ..Config::default() };
    banded_svd::util::prop::check(
        "precision-sweep",
        &cfg,
        |rng| {
            let bw = rng.range_inclusive(2, 8);
            let tw = rng.range_inclusive(1, bw - 1);
            let n = rng.range_inclusive(bw + 2, 48);
            (n, bw, tw, rng.next_u64())
        },
        |&(n, bw, tw, seed)| {
            run::<f64>(n, bw, tw, seed)?;
            run::<f32>(n, bw, tw, seed)?;
            run::<F16>(n, bw, tw, seed)
        },
    );
}
