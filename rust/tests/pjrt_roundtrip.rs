//! Integration: the AOT JAX/Pallas artifacts executed through PJRT must
//! produce the same reduction as the native Rust executor.
//!
//! Requires `make artifacts` (the tests skip with a loud message if the
//! artifact directory is absent, so plain `cargo test` stays usable
//! before the first build).

use banded_svd::backend::{AsBandStorageMut, Backend, PjrtBackend};
use banded_svd::banded::storage::Banded;
use banded_svd::config::{BackendKind, PackingPolicy, TuneParams};
use banded_svd::coordinator::Coordinator;
use banded_svd::generate::random_banded;
use banded_svd::pipeline::{bidiagonal_singular_values, relative_sv_error};
use banded_svd::plan::LaunchPlan;
use banded_svd::runtime::{artifact_dir, Manifest, PjrtEngine};
use banded_svd::util::rng::Xoshiro256;

fn have_variant(n: usize, bw: usize, tw: usize) -> bool {
    artifact_dir().join(Manifest::file_name(n, bw, tw)).exists()
}

fn skip(name: &str) {
    eprintln!("SKIPPED {name}: artifacts missing — run `make artifacts` first");
}

/// Native f32 reduction for comparison.
fn native_reduce(a: &Banded<f32>, bw: usize, tw: usize) -> Banded<f32> {
    let mut work = a.clone();
    let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
    banded_svd::bulge::reduce_to_bidiagonal(&mut work, bw, &params);
    work
}

#[test]
fn per_cycle_pjrt_matches_native() {
    let (n, bw, tw) = (96, 6, 3);
    if !have_variant(n, bw, tw) {
        return skip("per_cycle_pjrt_matches_native");
    }
    let engine = PjrtEngine::load(&artifact_dir(), n, bw, tw).unwrap();
    let mut rng = Xoshiro256::seed_from_u64(11);
    let a0 = random_banded::<f32>(n, bw, tw, &mut rng);
    let native = native_reduce(&a0, bw, tw);

    let mut pjrt = a0.clone();
    let stats = engine.reduce_banded(&mut pjrt, false).unwrap();
    assert_eq!(stats.launches, 274 + 280);

    // Same schedule, same reflector formulas — but f32 rounding can flip
    // a reflector's sign branch on a near-zero pivot, flipping signs of
    // rows/columns downstream (orthogonally equivalent results). The
    // robust invariants: bidiagonal form, element magnitudes, and the
    // singular values (checked strictly in a separate test).
    assert!(pjrt.max_off_band(1) < 1e-4, "not bidiagonal: {}", pjrt.max_off_band(1));
    let (dn, en) = native.bidiagonal();
    let (dp, ep) = pjrt.bidiagonal();
    let scale = native.fro_norm();
    for (x, y) in dn.iter().zip(dp.iter()).chain(en.iter().zip(ep.iter())) {
        assert!(
            (x.abs() - y.abs()).abs() as f64 <= 5e-3 * scale.max(1.0),
            "|{x}| vs |{y}|"
        );
    }
}

#[test]
fn fused_pjrt_matches_per_cycle_exactly() {
    let (n, bw, tw) = (96, 6, 3);
    if !have_variant(n, bw, tw) {
        return skip("fused_pjrt_matches_per_cycle_exactly");
    }
    let engine = PjrtEngine::load(&artifact_dir(), n, bw, tw).unwrap();
    assert!(engine.has_fused());
    let mut rng = Xoshiro256::seed_from_u64(12);
    let a0 = random_banded::<f32>(n, bw, tw, &mut rng);

    let mut per_cycle = a0.clone();
    engine.reduce_banded(&mut per_cycle, false).unwrap();
    let mut fused = a0.clone();
    engine.reduce_banded(&mut fused, true).unwrap();
    // Identical op sequence, identical compiled kernels: results should
    // agree to the bit or within denormal-level noise.
    for (x, y) in per_cycle.data().iter().zip(fused.data().iter()) {
        assert!((x - y).abs() <= 1e-6, "{x} vs {y}");
    }
}

#[test]
fn pjrt_preserves_singular_values() {
    let (n, bw, tw) = (96, 6, 3);
    if !have_variant(n, bw, tw) {
        return skip("pjrt_preserves_singular_values");
    }
    let engine = PjrtEngine::load(&artifact_dir(), n, bw, tw).unwrap();
    let mut rng = Xoshiro256::seed_from_u64(13);
    let a0 = random_banded::<f64>(n, bw, tw, &mut rng);
    // Ground truth via the f64 native path.
    let mut native = a0.clone();
    let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
    let res = banded_svd::bulge::reduce_to_bidiagonal(&mut native, bw, &params);
    let sv_native = bidiagonal_singular_values(&res.diag, &res.superdiag);

    let mut pjrt: Banded<f32> = a0.convert();
    engine.reduce_banded(&mut pjrt, true).unwrap();
    let (d, e) = pjrt.bidiagonal();
    let sv_pjrt = bidiagonal_singular_values(
        &d.iter().map(|v| *v as f64).collect::<Vec<_>>(),
        &e.iter().map(|v| *v as f64).collect::<Vec<_>>(),
    );
    let err = relative_sv_error(&sv_pjrt, &sv_native);
    assert!(err < 5e-5, "relative sv error {err}");
}

#[test]
fn coordinator_pjrt_backends_report_schedule_metrics() {
    let (n, bw, tw) = (96, 6, 3);
    if !have_variant(n, bw, tw) {
        return skip("coordinator_pjrt_backends_report_schedule_metrics");
    }
    let engine = PjrtEngine::load(&artifact_dir(), n, bw, tw).unwrap();
    let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
    let coord = Coordinator::new(params, 2);
    let mut rng = Xoshiro256::seed_from_u64(14);

    let mut a: Banded<f32> = random_banded::<f32>(n, bw, tw, &mut rng);
    let r1 = coord.reduce_pjrt(&engine, &mut a, BackendKind::Pjrt).unwrap();
    let mut b: Banded<f32> = random_banded::<f32>(n, bw, tw, &mut rng);
    let r2 = coord.reduce_pjrt(&engine, &mut b, BackendKind::PjrtFused).unwrap();
    assert_eq!(r1.metrics.launches, r2.metrics.launches);
    assert_eq!(r1.metrics.tasks, r2.metrics.tasks);
    assert!(r1.residual_off_band < 1e-4);
    assert!(r2.residual_off_band < 1e-4);
}

#[test]
fn plan_driven_backend_matches_the_manifest_driven_loop() {
    // The PjrtBackend walks the LaunchPlan launch by launch (skipping
    // empty cycles) through the same per-launch artifacts the legacy
    // manifest-driven loop executes for every cycle index; the chased
    // storage must agree.
    let (n, bw, tw) = (96, 6, 3);
    if !have_variant(n, bw, tw) {
        return skip("plan_driven_backend_matches_the_manifest_driven_loop");
    }
    let engine = PjrtEngine::load(&artifact_dir(), n, bw, tw).unwrap();
    let mut rng = Xoshiro256::seed_from_u64(15);
    let a0 = random_banded::<f32>(n, bw, tw, &mut rng);

    let mut legacy = a0.clone();
    engine.reduce_banded(&mut legacy, false).unwrap();

    let backend = PjrtBackend::with_engine(engine);
    assert!(backend.requires_artifacts());
    let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
    let plan = LaunchPlan::for_problem(n, bw, &params);
    let mut plan_driven = a0.clone();
    let exec = backend
        .execute(&plan, &mut [plan_driven.as_band_storage_mut()])
        .unwrap();

    // Exactly the plan's launches executed — never the empty cycles the
    // legacy loop paid a PJRT call for.
    assert_eq!(exec.aggregate.launches, plan.num_launches());
    assert_eq!(exec.per_problem[0].tasks, plan.total_tasks());
    assert!(plan_driven.max_off_band(1) < 1e-4);
    for (x, y) in legacy.data().iter().zip(plan_driven.data().iter()) {
        assert!((x - y).abs() <= 1e-6, "{x} vs {y}");
    }
}

#[test]
fn plan_driven_backend_executes_merged_batch_plans_multi_buffer() {
    // The batch capability the ROADMAP was waiting on: a merged plan maps
    // onto one device-resident buffer per problem, and per-problem
    // results stay bitwise identical to that problem's solo run (the
    // merge preserves per-problem launch order).
    let (n, bw, tw) = (96, 6, 3);
    if !have_variant(n, bw, tw) {
        return skip("plan_driven_backend_executes_merged_batch_plans_multi_buffer");
    }
    let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
    let mut rng = Xoshiro256::seed_from_u64(16);
    let a0 = random_banded::<f32>(n, bw, tw, &mut rng);
    let b0 = random_banded::<f32>(n, bw, tw, &mut rng);
    let parts = [
        LaunchPlan::for_problem(n, bw, &params),
        LaunchPlan::for_problem(n, bw, &params),
    ];
    let merged = LaunchPlan::merge(&parts, 192, PackingPolicy::RoundRobin, 2);
    assert!(merged.co_scheduled_launches() > 0);

    let backend = PjrtBackend::from_env();
    let mut a = a0.clone();
    let mut b = b0.clone();
    let exec = backend
        .execute(&merged, &mut [a.as_band_storage_mut(), b.as_band_storage_mut()])
        .unwrap();
    assert_eq!(exec.per_problem.len(), 2);
    assert_eq!(exec.aggregate.launches, merged.num_launches());

    let mut solo_a = a0.clone();
    backend
        .execute(&parts[0], &mut [solo_a.as_band_storage_mut()])
        .unwrap();
    assert_eq!(a, solo_a, "batched problem 0 diverged from its solo run");
    assert!(b.max_off_band(1) < 1e-4);
}

#[test]
fn manifest_layout_matches_banded_storage() {
    let (n, bw, tw) = (256, 8, 4);
    if !have_variant(n, bw, tw) {
        return skip("manifest_layout_matches_banded_storage");
    }
    let m = Manifest::load(&artifact_dir(), n, bw, tw).unwrap();
    let a = Banded::<f32>::for_reduction(n, bw, tw);
    assert_eq!(m.ld, a.ld());
    assert_eq!(m.kd_super, a.kd_super());
    assert_eq!(m.kd_sub, a.kd_sub());
}

#[test]
fn missing_variant_is_a_clean_error() {
    let msg = match PjrtEngine::load(&artifact_dir(), 12345, 8, 4) {
        Ok(_) => panic!("expected missing-artifact error"),
        Err(e) => e.to_string(),
    };
    assert!(msg.contains("make artifacts"), "{msg}");
}
