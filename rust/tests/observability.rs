//! End-to-end tracing contract: one trace id covers a job's whole
//! lifecycle — `submit` (client) → `admit` → `queue_wait` → `merge` →
//! `flush` → `launch[i]` → `respond` (server) → `respond` (client) —
//! whether the job ran through the embedded queued service, over the
//! JSON-lines wire, or across a sharded fleet that lost an endpoint
//! mid-request. Tests share one process-wide capture ring and filter by
//! their own trace ids, so they compose under the parallel test runner.

use banded_svd::client::{
    Client, LocalClient, ReductionRequest, RemoteClient, RouteStrategy, ShardedClient,
};
use banded_svd::config::{
    BackendKind, BatchConfig, PackingPolicy, ServiceConfig, ShardRouting, TuneParams,
};
use banded_svd::obs::trace::{self, TraceEvent, TraceId};
use banded_svd::scalar::ScalarKind;
use banded_svd::service::Server;
use banded_svd::util::json::Json;
use std::time::Duration;

fn params() -> TuneParams {
    TuneParams { tpb: 32, tw: 4, max_blocks: 24 }
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        params: params(),
        batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
        backend: BackendKind::Sequential,
        threads: 1,
        window: Duration::from_millis(2),
        queue_cap: 256,
        backlog_cap_s: 1e9,
        cache_cap: 32,
        arch: "H100",
        workers: 1,
        routing: ShardRouting::LeastLoaded,
        quota_pending_cap: 0,
        vectors_cap_n: banded_svd::config::DEFAULT_VECTORS_CAP_N,
    }
}

/// This test binary's events for one trace id, in ring (chronological)
/// order.
fn spans_for(id: TraceId) -> Vec<TraceEvent> {
    trace::snapshot().into_iter().filter(|e| e.trace == id).collect()
}

fn has_span(events: &[TraceEvent], span: &str, side: &str) -> bool {
    events.iter().any(|e| e.span == span && e.side == side)
}

#[test]
fn queued_jobs_emit_a_complete_span_chain_under_one_trace_id() {
    trace::enable_capture();
    let client = LocalClient::queued(service_cfg()).expect("queued client");
    let id = TraceId::mint();
    let request = ReductionRequest::new().random(40, 5, ScalarKind::F64, 11).trace(id);
    let outcome = client.submit_wait(request).expect("reduction");
    assert_eq!(outcome.problems.len(), 1);

    let events = spans_for(id);
    for (span, side) in [
        ("submit", "client"),
        ("admit", "server"),
        ("queue_wait", "server"),
        ("merge", "server"),
        ("flush", "server"),
        ("respond", "server"),
        ("respond", "client"),
    ] {
        assert!(has_span(&events, span, side), "missing {span}/{side} in {events:?}");
    }
    assert!(
        events.iter().any(|e| e.side == "server" && e.span.starts_with("launch[")),
        "no per-launch events attributed to the job: {events:?}"
    );

    // Every server-side span names the same admitted job, and admission
    // records which shard took it.
    let admit = events.iter().find(|e| e.span == "admit").expect("admit event");
    assert!(admit.job > 0, "admission assigns a nonzero job id");
    assert!(admit.shard.is_some(), "admission records the routed shard");
    for e in events.iter().filter(|e| e.side == "server") {
        assert_eq!(e.job, admit.job, "server span {} names a different job", e.span);
    }

    // Both exporters render the chain as well-formed JSON.
    for line in trace::jsonl(&events).lines() {
        let v = Json::parse(line).expect("jsonl line parses");
        assert_eq!(v.get("trace").and_then(Json::as_str), Some(id.to_hex()).as_deref());
        assert!(v.get("span").is_some() && v.get("side").is_some(), "{line}");
    }
    let chrome = Json::parse(&trace::chrome_trace(&events)).expect("chrome export parses");
    let chrome_events = chrome.get("traceEvents").and_then(Json::as_array).expect("traceEvents");
    assert_eq!(chrome_events.len(), events.len());
}

#[test]
fn one_minted_id_spans_every_problem_of_a_request() {
    trace::enable_capture();
    let client = LocalClient::queued(service_cfg()).expect("queued client");
    // No explicit trace id: with tracing live the client mints one per
    // *request*, and both problems ride it. n=57 is unique to this test,
    // so the submit events are recognizable in the shared ring.
    let request = ReductionRequest::new()
        .random(57, 6, ScalarKind::F64, 21)
        .random(57, 6, ScalarKind::F32, 22);
    client.submit_wait(request).expect("reduction");

    let submits: Vec<TraceEvent> = trace::snapshot()
        .into_iter()
        .filter(|e| e.span == "submit" && e.side == "client" && e.detail.starts_with("n=57 "))
        .collect();
    assert_eq!(submits.len(), 2, "one client submit per problem: {submits:?}");
    let id = submits[0].trace;
    assert_ne!(id, TraceId(0), "tracing live mints a real id");
    assert!(submits.iter().all(|e| e.trace == id), "problems split across trace ids");

    // Two jobs completed under the one id — reconciled server-side.
    let events = spans_for(id);
    let mut responded: Vec<u64> = events
        .iter()
        .filter(|e| e.span == "respond" && e.side == "server")
        .map(|e| e.job)
        .collect();
    responded.sort_unstable();
    responded.dedup();
    assert_eq!(responded.len(), 2, "both jobs respond under the request's id: {events:?}");
}

#[test]
fn remote_wire_propagates_the_trace_id_and_reconciles_job_ids() {
    trace::enable_capture();
    let server = Server::bind(service_cfg(), "127.0.0.1:0").expect("bind ephemeral");
    let addr = server.local_addr().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let remote = RemoteClient::connect(&addr).expect("remote client");
    let id = TraceId::mint();
    let request = ReductionRequest::new().random(44, 5, ScalarKind::F64, 31).trace(id);
    remote.submit_wait(request).expect("served reduction");

    // Client and server run in one process here, so one capture ring
    // holds both sides of the wire: the id the client wrote into the
    // request line is the id the server's spans carry.
    let events = spans_for(id);
    for (span, side) in [
        ("submit", "client"),
        ("admit", "server"),
        ("respond", "server"),
        ("respond", "client"),
    ] {
        assert!(has_span(&events, span, side), "missing {span}/{side} in {events:?}");
    }
    let s_respond =
        events.iter().find(|e| e.span == "respond" && e.side == "server").expect("server respond");
    let c_respond =
        events.iter().find(|e| e.span == "respond" && e.side == "client").expect("client respond");
    assert_eq!(
        s_respond.job, c_respond.job,
        "client and server disagree on which job answered: {events:?}"
    );

    remote.shutdown().expect("shutdown");
    server_thread.join().expect("server thread").expect("clean shutdown");
}

#[test]
fn failover_keeps_one_span_chain_per_request() {
    trace::enable_capture();
    let server_a = Server::bind(service_cfg(), "127.0.0.1:0").expect("bind a");
    let server_b = Server::bind(service_cfg(), "127.0.0.1:0").expect("bind b");
    let addr_a = server_a.local_addr().to_string();
    let addr_b = server_b.local_addr().to_string();
    let thread_a = std::thread::spawn(move || server_a.run());
    let thread_b = std::thread::spawn(move || server_b.run());

    let sharded =
        ShardedClient::connect(&[addr_a.as_str(), addr_b.as_str()], RouteStrategy::LeastLoaded)
            .expect("sharded client");
    assert_eq!(sharded.healthy(), 2);

    // Kill endpoint A over its own control connection. The sharded
    // client still holds A's (now dead) socket, so whichever of the two
    // requests routes there must fail over to B — under the *same*
    // trace id, because the id is pinned before the failover loop.
    RemoteClient::connect(&addr_a).expect("control connection").shutdown().expect("ack");
    thread_a.join().expect("server a thread").expect("clean shutdown");

    let ids = [TraceId::mint(), TraceId::mint()];
    for (i, &id) in ids.iter().enumerate() {
        let request =
            ReductionRequest::new().random(48, 6, ScalarKind::F64, 41 + i as u64).trace(id);
        sharded.submit_wait(request).expect("failover absorbs the dead endpoint");
    }
    assert_eq!(sharded.healthy(), 1, "the dead endpoint must be marked down");

    let mut saw_failover_retry = false;
    for &id in &ids {
        let events = spans_for(id);
        // Exactly one server answered — the job ran once, on the
        // survivor, never on both endpoints.
        let responds =
            events.iter().filter(|e| e.span == "respond" && e.side == "server").count();
        assert_eq!(responds, 1, "one server respond for {id:?}: {events:?}");
        assert!(has_span(&events, "respond", "client"), "client respond for {id:?}");
        // A failed-over request shows >1 client submit attempt, all
        // under the pinned id (that is the point of pinning).
        let submits =
            events.iter().filter(|e| e.span == "submit" && e.side == "client").count();
        assert!(submits >= 1, "at least the winning attempt: {events:?}");
        saw_failover_retry |= submits > 1;
    }
    assert!(
        saw_failover_retry,
        "least-loaded rotation must have routed one request to the dead endpoint first"
    );

    sharded.shutdown().expect("fleet shutdown");
    thread_b.join().expect("server b thread").expect("clean shutdown");
}
