//! The singular-vector acceptance harness: over random banded shapes,
//! bandwidths, and seeds in both working precisions, every registered
//! backend that can run without pre-compiled artifacts must produce a
//! full SVD `A = U · Σ · Vᵀ` that
//!
//!  * reconstructs the input: `‖A − U·Σ·Vᵀ‖_F ≤ c·ε·‖A‖_F`,
//!  * is orthogonal: `‖UᵀU − I‖_F, ‖V Vᵀ... − I‖_F ≤ c·ε·√n`, and
//!  * is **bitwise identical** to the sequential oracle — panels and
//!    singular values alike, from any backend (threadpool, SIMD on any
//!    ISA arm the registry resolves, including `BSVD_SIMD=force` /
//!    `BSVD_SIMD=off` in CI).
//!
//! `ε` is the *working* precision's machine epsilon (`f32::EPSILON` for
//! f32 inputs — the band stage commits its rounding in `T` even though
//! the panels themselves accumulate in f64).

use banded_svd::backend::{for_kind, AsBandStorageMut, SequentialBackend};
use banded_svd::banded::dense::Dense;
use banded_svd::banded::Banded;
use banded_svd::config::{BackendKind, TuneParams};
use banded_svd::generate::random_banded;
use banded_svd::pipeline::{banded_svd_vectors_with, SvdVectors};
use banded_svd::scalar::Scalar;
use banded_svd::util::prop::{check, Config};
use banded_svd::util::rng::Xoshiro256;

/// The `c` in the acceptance bounds. Backward-stable Householder and
/// Givens chains accumulate error like a modest polynomial in `n`; at
/// the sweep's sizes (n ≤ 192) a flat 4096·ε covers that with a wide
/// safety margin while still catching any structural mistake (a
/// dropped rotation, a misordered replay, a wrong sign fix-up — all of
/// which show up at O(1), not O(ε)).
const C: f64 = 4096.0;

fn dense_f64_of<T: Scalar>(banded: &Banded<T>) -> Dense<f64> {
    let n = banded.n();
    let data = banded.to_dense().into_iter().map(|v| v.to_f64()).collect();
    Dense::from_vec(n, n, data)
}

/// `‖a − b‖_F` over two same-shape dense matrices.
fn fro_diff(a: &Dense<f64>, b: &Dense<f64>) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    a.data
        .iter()
        .zip(b.data.iter())
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// `‖GᵀG − I‖_F` — the Frobenius orthogonality defect of a square
/// factor.
fn gram_defect(g: &Dense<f64>) -> f64 {
    let gram = g.transpose().matmul(g);
    let n = gram.rows;
    let mut s = 0.0;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            let d = gram.get(i, j) - want;
            s += d * d;
        }
    }
    s.sqrt()
}

/// `U · diag(sv) · Vᵀ`.
fn reconstruct(svd: &SvdVectors) -> Dense<f64> {
    let mut sigma_vt = svd.vt.clone();
    for (k, &s) in svd.sv.iter().enumerate() {
        for v in sigma_vt.row_mut(k) {
            *v *= s;
        }
    }
    svd.u.matmul(&sigma_vt)
}

/// Run one `(n, bw, tw, seed)` case in working precision `T`: sequential
/// oracle first, then every artifact-free registry backend against it.
fn residual_case<T: Scalar>(n: usize, bw: usize, tw: usize, seed: u64) -> Result<(), String>
where
    Banded<T>: AsBandStorageMut,
{
    let params = TuneParams { tpb: 32, tw, max_blocks: 16 };
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let banded = random_banded::<T>(n, bw, params.effective_tw(bw), &mut rng);
    let a0 = dense_f64_of(&banded);
    let scale = a0.fro_norm().max(1e-300);
    let resid_bound = C * T::EPS * scale;
    let ortho_bound = C * T::EPS * (n as f64).sqrt();

    let oracle = banded_svd_vectors_with(&SequentialBackend::new(), &banded, bw, &params)
        .map_err(|e| e.to_string())?;

    let mut compared = 0;
    for kind in BackendKind::ALL {
        let backend = match for_kind(kind, 3) {
            Ok(b) => b,
            // pjrt-fused has no plan-executor (vectors-capable) form.
            Err(_) => continue,
        };
        if backend.requires_artifacts() {
            continue;
        }
        let svd = banded_svd_vectors_with(backend.as_ref(), &banded, bw, &params)
            .map_err(|e| format!("{kind:?}: {e}"))?;

        if svd.sv.len() != n || !svd.sv.windows(2).all(|w| w[0] >= w[1]) {
            return Err(format!("{kind:?}: singular values not descending (n={n}, bw={bw})"));
        }
        let resid = fro_diff(&reconstruct(&svd), &a0);
        if resid > resid_bound {
            return Err(format!(
                "{kind:?} ({prec}): ‖A − UΣVᵀ‖_F = {resid:e} exceeds {resid_bound:e} \
                 (n={n}, bw={bw}, seed={seed})",
                prec = T::NAME
            ));
        }
        for (label, panel) in [("UᵀU", &svd.u), ("VVᵀ", &svd.vt)] {
            let defect = gram_defect(panel);
            if defect > ortho_bound {
                return Err(format!(
                    "{kind:?} ({prec}): ‖{label} − I‖_F = {defect:e} exceeds {ortho_bound:e} \
                     (n={n}, bw={bw}, seed={seed})",
                    prec = T::NAME
                ));
            }
        }
        // The defining constraint: vectors from any backend are bitwise
        // what the sequential oracle computes — not merely close.
        if svd.sv != oracle.sv {
            return Err(format!("{kind:?}: singular values differ bitwise from sequential"));
        }
        if svd.u != oracle.u || svd.vt != oracle.vt {
            return Err(format!("{kind:?}: U/Vᵀ panels differ bitwise from sequential"));
        }
        compared += 1;
    }
    if compared < 2 {
        return Err(format!("only {compared} native backends registered; expected ≥ 2"));
    }
    Ok(())
}

#[derive(Debug)]
struct Case {
    n: usize,
    bw: usize,
    tw: usize,
    seed: u64,
}

fn gen_case(rng: &mut Xoshiro256) -> Case {
    let bw = rng.range_inclusive(2, 12);
    Case {
        n: rng.range_inclusive(bw + 4, 80),
        bw,
        tw: rng.range_inclusive(1, 8),
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_full_svd_reconstructs_in_f64() {
    let cfg = Config { cases: 16, ..Config::default() };
    check("svd-residual-f64", &cfg, gen_case, |case| {
        residual_case::<f64>(case.n, case.bw, case.tw, case.seed)
    });
}

#[test]
fn prop_full_svd_reconstructs_in_f32() {
    let cfg = Config { cases: 16, ..Config::default() };
    check("svd-residual-f32", &cfg, gen_case, |case| {
        residual_case::<f32>(case.n, case.bw, case.tw, case.seed)
    });
}

#[test]
fn wide_band_shapes_cross_the_packed_simd_gate() {
    // The property sweep stays below the packed-kernel gate (`b + d ≥
    // 48`); these shapes cross it, so a CI leg running this file under
    // `BSVD_SIMD=force` proves the packed lane kernels feed the
    // reflector log with the same bits as everything else.
    for (n, bw, tw, seed) in [(192usize, 40usize, 32usize, 71u64), (160, 24, 24, 72)] {
        residual_case::<f64>(n, bw, tw, seed).unwrap();
    }
    residual_case::<f32>(144, 40, 16, 73).unwrap();
}
