//! Quickstart: reduce a random banded matrix to bidiagonal form and
//! compute its singular values — the three-line public API.
//!
//! Run: `cargo run --release --example quickstart`

use banded_svd::prelude::*;

fn main() {
    let n = 512;
    let bw = 16; // superdiagonals
    let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };

    // A random upper-banded matrix in working storage.
    let mut rng = Xoshiro256::seed_from_u64(0);
    let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
    let norm = a.fro_norm();

    // Stage 2: memory-aware bulge chasing with bandwidth tiling.
    let t0 = std::time::Instant::now();
    let result = reduce_to_bidiagonal(&mut a, bw, &params);
    let reduce_time = t0.elapsed();

    // Stage 3: singular values of the bidiagonal.
    let sv = bidiagonal_singular_values(&result.diag, &result.superdiag);

    println!("n = {n}, bandwidth = {bw}, tilewidth = {}", params.effective_tw(bw));
    println!(
        "stages: {:?}",
        result.stages.iter().map(|s| (s.b, s.d)).collect::<Vec<_>>()
    );
    println!(
        "reduced in {reduce_time:?} ({} launches, {} bulge tasks)",
        result.total_launches, result.total_tasks
    );
    println!("σ_max = {:.6}, σ_min = {:.6}", sv[0], sv[n - 1]);
    println!(
        "‖A‖_F = {:.6} vs sqrt(Σσ²) = {:.6} (orthogonal invariance check)",
        norm,
        sv.iter().map(|s| s * s).sum::<f64>().sqrt()
    );
    assert_eq!(a.max_off_band(1), 0.0, "matrix is exactly bidiagonal");
    println!("OK");
}
