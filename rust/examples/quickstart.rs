//! Quickstart: reduce a random banded matrix to bidiagonal form and
//! compute its singular values — first through the kernel-level API
//! (what the machinery does), then through the unified client front
//! door (how applications should call it).
//!
//! Run: `cargo run --release --example quickstart`

use banded_svd::prelude::*;

fn main() {
    let n = 512;
    let bw = 16; // superdiagonals
    let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };

    // A random upper-banded matrix in working storage.
    let mut rng = Xoshiro256::seed_from_u64(0);
    let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
    let norm = a.fro_norm();

    // Stage 2: memory-aware bulge chasing with bandwidth tiling.
    let t0 = std::time::Instant::now();
    let result = reduce_to_bidiagonal(&mut a, bw, &params);
    let reduce_time = t0.elapsed();

    // Stage 3: singular values of the bidiagonal.
    let sv = bidiagonal_singular_values(&result.diag, &result.superdiag);

    println!("n = {n}, bandwidth = {bw}, tilewidth = {}", params.effective_tw(bw));
    println!(
        "stages: {:?}",
        result.stages.iter().map(|s| (s.b, s.d)).collect::<Vec<_>>()
    );
    println!(
        "reduced in {reduce_time:?} ({} launches, {} bulge tasks)",
        result.total_launches, result.total_tasks
    );
    println!("σ_max = {:.6}, σ_min = {:.6}", sv[0], sv[n - 1]);
    println!(
        "‖A‖_F = {:.6} vs sqrt(Σσ²) = {:.6} (orthogonal invariance check)",
        norm,
        sv.iter().map(|s| s * s).sum::<f64>().sqrt()
    );
    assert_eq!(a.max_off_band(1), 0.0, "matrix is exactly bidiagonal");

    // The same computation through the unified client front door — one
    // request/outcome contract shared with batching, the queued service,
    // and remote serving (`banded-svd serve` + RemoteClient).
    let client = LocalClient::new(params);
    let outcome = client
        .submit_wait(ReductionRequest::new().random(n, bw, ScalarKind::F64, 0))
        .expect("reduction");
    let p = &outcome.problems[0];
    for (a, b) in p.sv.iter().zip(sv.iter()) {
        assert!((a - b).abs() <= 1e-12 * sv[0], "front door disagrees: {a} vs {b}");
    }
    println!(
        "client front door: {} on {} agrees ({} launches)",
        outcome.provenance.source.name(),
        outcome.provenance.backend,
        p.metrics.launches
    );
    println!("OK");
}
