//! Batched reduction demo through the unified client: eight banded
//! problems of mixed size, bandwidth, and precision reduced in one
//! interleaved batch, compared against the same problems submitted one
//! request at a time — the many-small-matrices workload (covariance
//! spectra, per-head attention blocks) a single-problem call cannot
//! saturate the device with.
//!
//! Run: `cargo run --release --example batch_throughput`

use banded_svd::client::{Client, LocalClient, ReductionRequest};
use banded_svd::config::TuneParams;
use banded_svd::scalar::ScalarKind;
use banded_svd::util::bench::{fmt_duration, Table};
use std::time::Duration;

fn main() {
    let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };
    let client = LocalClient::new(params);

    // A heterogeneous batch: covariance-sized f64 blocks, attention-head
    // f32 blocks, and a couple of f16 probes.
    let shapes: [(usize, usize, ScalarKind); 8] = [
        (384, 16, ScalarKind::F64),
        (256, 12, ScalarKind::F64),
        (320, 16, ScalarKind::F64),
        (192, 8, ScalarKind::F64),
        (128, 8, ScalarKind::F32),
        (160, 8, ScalarKind::F32),
        (96, 6, ScalarKind::F16),
        (96, 6, ScalarKind::F16),
    ];
    let request = |seed_base: u64| {
        let mut request = ReductionRequest::new();
        for (i, &(n, bw, kind)) in shapes.iter().enumerate() {
            request = request.random(n, bw, kind, seed_base.wrapping_add(i as u64));
        }
        request
    };

    let outcome = client.submit_wait(request(7)).expect("batched reduction");
    let batch_wall = outcome.wall;

    let mut table = Table::new(vec!["problem", "precision", "n", "bw", "launches", "sigma_max"]);
    for (i, p) in outcome.problems.iter().enumerate() {
        assert_eq!(p.residual_off_band, Some(0.0), "problem {i} not fully reduced");
        table.row(vec![
            i.to_string(),
            p.precision.to_string(),
            p.n.to_string(),
            p.bw.to_string(),
            p.metrics.launches.to_string(),
            format!("{:.4}", p.sv[0]),
        ]);
    }
    table.print();

    // Reference: the same f64 problems one request at a time through the
    // same client (batch size 1 — no co-scheduling).
    let mut solo_wall = Duration::ZERO;
    for (i, &(n, bw, kind)) in shapes.iter().enumerate() {
        if kind != ScalarKind::F64 {
            continue;
        }
        let solo = client
            .submit_wait(ReductionRequest::new().random(n, bw, kind, 7u64.wrapping_add(i as u64)))
            .expect("solo reduction");
        solo_wall += solo.wall;
        // Same problem, same backend: the batched submission answered
        // exactly this (the merge preserves per-problem launch order).
        assert_eq!(solo.problems[0].sv, outcome.problems[i].sv, "problem {i}");
    }

    let batch = outcome.batch.as_ref().expect("direct mode reports batch metrics");
    println!(
        "\nbatched: {} problems in {} ({:.1} problems/s), \
         {} shared launches, occupancy {:.2}, {} co-scheduled",
        outcome.problems.len(),
        fmt_duration(batch_wall),
        outcome.throughput(),
        batch.aggregate.launches,
        batch.occupancy_ratio(),
        batch.co_scheduled_launches
    );
    println!(
        "solo   : f64 problems back to back in {} (batch also covered these, bitwise)",
        fmt_duration(solo_wall)
    );
    println!(
        "provenance: {} on {} (plan cache: {} hits)",
        outcome.provenance.source.name(),
        outcome.provenance.backend,
        outcome.provenance.cache.map(|c| c.hits()).unwrap_or(0)
    );
}
