//! Batched reduction demo: eight banded problems of mixed size,
//! bandwidth, and precision reduced in one interleaved batch, compared
//! against the same problems run one at a time — the many-small-matrices
//! workload (covariance spectra, per-head attention blocks) the
//! single-problem API cannot saturate the device with.
//!
//! Run: `cargo run --release --example batch_throughput`

use banded_svd::banded::storage::Banded;
use banded_svd::batch::{BatchCoordinator, BatchInput};
use banded_svd::config::{BackendKind, BatchConfig, TuneParams};
use banded_svd::coordinator::Coordinator;
use banded_svd::generate::random_banded;
use banded_svd::scalar::F16;
use banded_svd::util::bench::{fmt_duration, Table};
use banded_svd::util::rng::Xoshiro256;
use std::time::Instant;

fn main() {
    let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };
    let mut rng = Xoshiro256::seed_from_u64(7);

    // A heterogeneous batch: covariance-sized f64 blocks, attention-head
    // f32 blocks, and a couple of f16 probes.
    let mut inputs: Vec<BatchInput> = Vec::new();
    let mut solo_f64: Vec<(Banded<f64>, usize)> = Vec::new();
    for &(n, bw) in &[(384usize, 16usize), (256, 12), (320, 16), (192, 8)] {
        let a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        solo_f64.push((a.clone(), bw));
        inputs.push(BatchInput::from((a, bw)));
    }
    for &(n, bw) in &[(128usize, 8usize), (160, 8)] {
        let a = random_banded::<f32>(n, bw, params.effective_tw(bw), &mut rng);
        inputs.push(BatchInput::from((a, bw)));
    }
    for &(n, bw) in &[(96usize, 6usize), (96, 6)] {
        let a = random_banded::<F16>(n, bw, params.effective_tw(bw), &mut rng);
        inputs.push(BatchInput::from((a, bw)));
    }

    let coord = BatchCoordinator::new(params, BatchConfig::default(), 0);
    let plan = coord.plan(&inputs).expect("plan");
    println!(
        "batch of {} problems: {} tasks, {} per-problem launches, >= {} shared launches\n",
        plan.problems.len(),
        plan.total_tasks(),
        plan.total_launches(),
        plan.min_shared_launches()
    );

    let t0 = Instant::now();
    let report = coord.run(&mut inputs).expect("batched reduction");
    let batch_wall = t0.elapsed();

    let mut table = Table::new(vec!["problem", "precision", "n", "bw", "launches", "sigma_max"]);
    for (i, p) in report.problems.iter().enumerate() {
        let sv =
            banded_svd::pipeline::bidiagonal_singular_values(&p.diag, &p.superdiag);
        assert_eq!(p.residual_off_band, 0.0, "problem {i} not fully reduced");
        table.row(vec![
            i.to_string(),
            p.precision.to_string(),
            p.n.to_string(),
            p.bw.to_string(),
            p.metrics.launches.to_string(),
            format!("{:.4}", sv[0]),
        ]);
    }
    table.print();

    // Reference: the f64 problems one at a time through the solo
    // coordinator (same backend, batch size 1).
    let solo_coord = Coordinator::new(params, 0);
    let t0 = Instant::now();
    for (a, bw) in &solo_f64 {
        let mut work = a.clone();
        solo_coord
            .reduce_native(&mut work, *bw, BackendKind::Threadpool)
            .expect("solo reduction");
    }
    let solo_wall = t0.elapsed();

    println!(
        "\nbatched: {} problems in {} ({:.1} problems/s), \
         {} shared launches, occupancy {:.2}, {} co-scheduled",
        report.problems.len(),
        fmt_duration(batch_wall),
        report.throughput(),
        report.metrics.aggregate.launches,
        report.metrics.occupancy_ratio(),
        report.metrics.co_scheduled_launches
    );
    println!(
        "solo   : {} f64 problems back to back in {} (batch also covered these)",
        solo_f64.len(),
        fmt_duration(solo_wall)
    );
}
