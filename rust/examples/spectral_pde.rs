//! Domain example: banded matrices "occur directly in applications such
//! as spectral methods for partial differential equations" (paper §I).
//!
//! We build the banded operator of an ultraspherical-style spectral
//! discretization of u'' + c·u' on n modes — a non-symmetric banded
//! matrix — and compute its singular values (condition number, rank
//! behaviour) through stages 2+3 directly, no dense detour.
//!
//! Run: `cargo run --release --example spectral_pde`

use banded_svd::banded::storage::Banded;
use banded_svd::client::{Client, LocalClient, ReductionRequest};
use banded_svd::config::TuneParams;
use banded_svd::scalar::Scalar;

/// Banded spectral operator: D2 + c·D1 in a coefficient basis where D2
/// is diagonal-ish and D1 couples neighbouring modes — upper-banded with
/// a small bandwidth, exactly the structure the paper's direct
/// application targets.
fn spectral_operator(n: usize, c: f64, bw: usize, tw: usize) -> Banded<f64> {
    let mut a = Banded::<f64>::for_reduction(n, bw, tw);
    for i in 0..n {
        let k = i as f64 + 1.0;
        // Second-derivative main weight (grows ~ k²: ill-conditioned).
        a.set(i, i, k * (k + 1.0));
        // First-derivative coupling to the next modes.
        for off in 1..=bw.min(n - 1 - i) {
            let w = c * k / (k + off as f64);
            a.set(i, i + off, if off % 2 == 1 { w } else { w / 2.0 });
        }
    }
    a
}

fn main() {
    let n = 1024;
    let bw = 4;
    let params = TuneParams { tpb: 32, tw: 2, max_blocks: 192 };
    let tw = params.effective_tw(bw);
    let client = LocalClient::new(params);

    for &c in &[0.0f64, 1.0, 10.0] {
        let op = spectral_operator(n, c, bw, tw);
        let t0 = std::time::Instant::now();
        let sv = client
            .submit_wait(ReductionRequest::new().problem((op.clone(), bw)))
            .expect("banded reduction")
            .problems
            .remove(0)
            .sv;
        let dt = t0.elapsed();
        let sigma_max = sv[0];
        let sigma_min = sv[n - 1].max(1e-300);
        println!(
            "c = {c:>5}: σ_max = {:.4e}, σ_min = {:.4e}, cond = {:.4e}  ({dt:?})",
            sigma_max,
            sigma_min,
            sigma_max / sigma_min
        );
        // Sanity: Frobenius identity.
        let fro = op.fro_norm();
        let ssq = sv.iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!(((fro - ssq) / fro).to_f64().abs() < 1e-10);
    }
    println!("spectral operator singular analysis OK");
}
