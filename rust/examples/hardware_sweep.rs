//! Hardware-model tour: occupancy (Table I), generation gains (Fig. 5),
//! cross-vendor portability (Fig. 7) and a hyperparameter mini-sweep
//! (Fig. 4) — everything the performance model predicts, in one run.
//!
//! Run: `cargo run --release --example hardware_sweep`

use banded_svd::config::TuneParams;
use banded_svd::simulator::{self, hw};
use banded_svd::util::bench::Table;

fn main() {
    // Table I.
    println!("— occupancy (Table I, CBW = 32) —");
    let mut t = Table::new(vec!["GPU", "ALUs", "n for full occupancy"]);
    for row in simulator::table1(32) {
        t.row(vec![row.arch.to_string(), row.alus.to_string(), row.n_required.to_string()]);
    }
    t.print();

    // Fig. 5: generation gains.
    println!("\n— architecture generations (Fig. 5 shape) —");
    let p = TuneParams { tpb: 32, tw: 32, max_blocks: 192 };
    let mut t = Table::new(vec!["n", "A100/H100", "MI250X/MI300X"]);
    for n in [4096usize, 16384, 65536] {
        let h = simulator::simulate_reduction(&hw::H100, 4, n, 64, &p).seconds;
        let a = simulator::simulate_reduction(&hw::A100, 4, n, 64, &p).seconds;
        let m3 = simulator::simulate_reduction(&hw::MI300X, 4, n, 64, &p).seconds;
        let m2 = simulator::simulate_reduction(&hw::MI250X, 4, n, 64, &p).seconds;
        t.row(vec![n.to_string(), format!("{:.2}x", a / h), format!("{:.2}x", m2 / m3)]);
    }
    t.print();

    // Fig. 7: cross-vendor, cross-precision.
    println!("\n— portability (Fig. 7 shape, n = 32768, bw = 32) —");
    let mut t = Table::new(vec!["GPU", "fp16", "fp32", "fp64"]);
    for arch in hw::all_archs() {
        let mut row = vec![arch.name.to_string()];
        for es in [2usize, 4, 8] {
            let p = TuneParams { tpb: 32, tw: (128 / es).min(31).max(1), max_blocks: 192 };
            let r = simulator::simulate_reduction(&arch, es, 32768, 32, &p);
            row.push(format!("{:.3} s", r.seconds));
        }
        t.row(row);
    }
    t.print();

    // Fig. 4 mini-sweep.
    println!("\n— tilewidth sweep on H100 (Fig. 4 headline) —");
    let mut t = Table::new(vec!["precision", "tw=8", "tw=16", "tw=32", "tw=64", "optimal"]);
    for (es, name) in [(4usize, "fp32"), (8, "fp64")] {
        let mut row = vec![name.to_string()];
        let mut best = (f64::INFINITY, 0usize);
        let mut vals = Vec::new();
        for tw in [8usize, 16, 32, 64] {
            let p = TuneParams { tpb: 32, tw, max_blocks: 192 };
            let s = simulator::simulate_reduction(&hw::H100, es, 65536, 128, &p).seconds;
            if s < best.0 {
                best = (s, tw);
            }
            vals.push(s);
        }
        for v in vals {
            row.push(format!("{v:.2} s"));
        }
        row.push(format!("tw={} (cache line = {} elems)", best.1, 128 / es));
        t.row(row);
    }
    t.print();
}
