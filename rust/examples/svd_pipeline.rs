//! End-to-end driver (DESIGN.md §4 "e2e"): the full three-stage
//! singular-value pipeline on a real small workload, with stage 2
//! executed BOTH natively and through the AOT JAX/Pallas artifacts via
//! PJRT — proving all layers compose. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Workload: a 256×256 matrix with prescribed quarter-circle spectrum
//! (the "random matrix" case of Fig. 3), reduced to bandwidth 8 by stage
//! 1, chased to bidiagonal by stage 2 (tilewidth 4), solved by stage 3.
//!
//! Run: `make artifacts && cargo run --release --example svd_pipeline`

use banded_svd::banded::storage::Banded;
use banded_svd::config::{BackendKind, TuneParams};
use banded_svd::coordinator::Coordinator;
use banded_svd::generate::{dense_with_spectrum, Spectrum};
use banded_svd::pipeline::{
    bidiagonal_singular_values, dense_to_band, relative_sv_error,
};
use banded_svd::runtime::{artifact_dir, PjrtEngine};
use banded_svd::util::bench::fmt_duration;
use banded_svd::util::rng::Xoshiro256;
use std::time::Instant;

fn main() {
    let (n, bw, tw) = (256usize, 8usize, 4usize);
    let params = TuneParams { tpb: 32, tw, max_blocks: 192 };

    // --- workload: known spectrum --------------------------------------
    let mut rng = Xoshiro256::seed_from_u64(2024);
    let sigma = Spectrum::QuarterCircle.sample(n, &mut rng);
    let dense = dense_with_spectrum(n, &sigma, &mut rng, 64);
    println!("workload: {n}x{n} dense, quarter-circle spectrum, bw={bw}, tw={tw}");

    // --- stage 1 (f64): dense -> banded ---------------------------------
    let t0 = Instant::now();
    let banded64 = dense_to_band(&dense, bw, tw);
    let t_stage1 = t0.elapsed();
    println!("stage 1 (dense→band, f64): {}", fmt_duration(t_stage1));

    // --- stage 2a: native coordinator (parallel launch loop) ------------
    let coord = Coordinator::new(params, 0);
    let mut native = banded64.clone();
    let rep = coord
        .reduce_native(&mut native, bw, BackendKind::Threadpool)
        .expect("native reduction");
    println!(
        "stage 2 native   : {} ({} launches, {} tasks, peak parallel {})",
        fmt_duration(rep.metrics.wall),
        rep.metrics.launches,
        rep.metrics.tasks,
        rep.metrics.max_parallel
    );
    let sv_native = bidiagonal_singular_values(&rep.diag, &rep.superdiag);

    // --- stage 2b: AOT JAX/Pallas artifacts through PJRT ---------------
    let engine = match PjrtEngine::load(&artifact_dir(), n, bw, tw) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "PJRT artifacts   : {} stages compiled in {}",
        engine.manifest().stages.len(),
        fmt_duration(engine.compile_time)
    );
    let mut pjrt: Banded<f32> = banded64.convert();
    let t0 = Instant::now();
    let stats = engine.reduce_banded(&mut pjrt, true).expect("fused PJRT reduction");
    println!(
        "stage 2 pjrt-fused: {} exec ({} launches inside {} stage calls)",
        fmt_duration(t0.elapsed()),
        stats.launches,
        stats.stages
    );
    let (d32, e32) = pjrt.bidiagonal();
    let sv_pjrt = bidiagonal_singular_values(
        &d32.iter().map(|v| *v as f64).collect::<Vec<_>>(),
        &e32.iter().map(|v| *v as f64).collect::<Vec<_>>(),
    );

    // --- stage 3 + verification -----------------------------------------
    let err_native = relative_sv_error(&sv_native, &sigma);
    let err_pjrt = relative_sv_error(&sv_pjrt, &sigma);
    let cross = relative_sv_error(&sv_pjrt, &sv_native);
    println!("singular values : σ_max {:.6}  σ_min {:.3e}", sv_native[0], sv_native[n - 1]);
    println!("rel-err native (f64 stage 2) vs ground truth: {err_native:.3e}");
    println!("rel-err pjrt   (f32 stage 2) vs ground truth: {err_pjrt:.3e}");
    println!("cross-path agreement (pjrt vs native)       : {cross:.3e}");

    assert!(err_native < 1e-10, "native accuracy regression");
    assert!(err_pjrt < 1e-4, "pjrt accuracy regression");
    assert!(cross < 1e-4, "paths diverged");
    println!("ALL LAYERS COMPOSE — OK");
}
