//! JSON-lines client for `banded-svd serve` — the quickstart transcript
//! in `docs/service.md` and the CI smoke driver.
//!
//! Opens one TCP connection per submitter thread, streams a mixed-shape
//! mixed-precision job load at the service (concurrent connections are
//! what feed the micro-batcher), sanity-checks every response, then
//! prints the service's own `stats` view. With `--shutdown` it also
//! stops the server — the CI smoke job asserts the clean-shutdown path.
//!
//! ```text
//! cargo run --release --example serve_client -- \
//!     --addr 127.0.0.1:7070 --jobs 16 --submitters 4 --shutdown
//! ```

use banded_svd::generate::random_banded;
use banded_svd::service::server::submit_request;
use banded_svd::util::json::Json;
use banded_svd::util::rng::Xoshiro256;
use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;

struct Opts {
    addr: String,
    jobs: usize,
    submitters: usize,
    seed: u64,
    shutdown: bool,
}

fn parse_opts() -> Result<Opts, String> {
    let mut opts = Opts {
        addr: "127.0.0.1:7070".to_string(),
        jobs: 8,
        submitters: 4,
        seed: 42,
        shutdown: false,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        let take = |i: &mut usize| -> Result<String, String> {
            *i += 1;
            argv.get(*i).cloned().ok_or_else(|| format!("{} needs a value", argv[*i - 1]))
        };
        match argv[i].as_str() {
            "--addr" => opts.addr = take(&mut i)?,
            "--jobs" => opts.jobs = take(&mut i)?.parse().map_err(|e| format!("--jobs: {e}"))?,
            "--submitters" => {
                opts.submitters = take(&mut i)?.parse().map_err(|e| format!("--submitters: {e}"))?
            }
            "--seed" => opts.seed = take(&mut i)?.parse().map_err(|e| format!("--seed: {e}"))?,
            "--shutdown" => opts.shutdown = true,
            other => {
                return Err(format!(
                    "unknown option {other:?} \
                     (--addr --jobs --submitters --seed --shutdown)"
                ))
            }
        }
        i += 1;
    }
    opts.jobs = opts.jobs.max(1);
    opts.submitters = opts.submitters.clamp(1, opts.jobs);
    Ok(opts)
}

/// One round-trip on an open connection.
fn roundtrip(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    line: &str,
) -> Result<Json, String> {
    writeln!(writer, "{line}").map_err(|e| format!("send: {e}"))?;
    writer.flush().map_err(|e| format!("flush: {e}"))?;
    let mut response = String::new();
    reader.read_line(&mut response).map_err(|e| format!("recv: {e}"))?;
    if response.is_empty() {
        return Err("server closed the connection".into());
    }
    Json::parse(response.trim_end()).map_err(|e| format!("bad response: {e}"))
}

fn connect(addr: &str) -> Result<(BufReader<TcpStream>, TcpStream), String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
    Ok((reader, stream))
}

/// The cycling job mix: (n, bw, precision).
const SHAPES: [(usize, usize, &str); 4] =
    [(96, 8, "fp64"), (64, 6, "fp32"), (48, 5, "fp64"), (80, 10, "fp32")];

fn submit_line(job: usize, seed: u64) -> String {
    let (n, bw, precision) = SHAPES[job % SHAPES.len()];
    let mut rng = Xoshiro256::seed_from_u64(seed.wrapping_add(job as u64));
    match precision {
        "fp32" => submit_request(&random_banded::<f32>(n, bw, 1, &mut rng), bw, 0),
        _ => submit_request(&random_banded::<f64>(n, bw, 1, &mut rng), bw, 0),
    }
}

fn check_submit_response(response: &Json) -> Result<(usize, usize), String> {
    if response.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(format!("rejected: {}", response.render()));
    }
    let n = response.get("n").and_then(Json::as_usize).ok_or("missing n")?;
    let sv = response.get("sv").and_then(Json::as_array).ok_or("missing sv")?;
    if sv.len() != n {
        return Err(format!("{} singular values for n={n}", sv.len()));
    }
    let values: Vec<f64> = sv.iter().filter_map(Json::as_f64).collect();
    if values.len() != n || values.windows(2).any(|w| w[0] < w[1]) {
        return Err("singular values not descending".into());
    }
    let batch_jobs =
        response.get("batch_jobs").and_then(Json::as_usize).ok_or("missing batch_jobs")?;
    Ok((n, batch_jobs))
}

fn main() {
    let opts = match parse_opts() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let failures = std::sync::atomic::AtomicUsize::new(0);
    let co_scheduled = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for submitter in 0..opts.submitters {
            let (opts, failures, co_scheduled) = (&opts, &failures, &co_scheduled);
            scope.spawn(move || {
                let (mut reader, mut writer) = match connect(&opts.addr) {
                    Ok(pair) => pair,
                    Err(e) => {
                        eprintln!("submitter {submitter}: {e}");
                        failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        return;
                    }
                };
                let mut job = submitter;
                while job < opts.jobs {
                    let line = submit_line(job, opts.seed);
                    match roundtrip(&mut reader, &mut writer, &line)
                        .and_then(|r| check_submit_response(&r))
                    {
                        Ok((n, batch_jobs)) => {
                            println!("job {job}: n={n} ok (batch of {batch_jobs})");
                            if batch_jobs > 1 {
                                co_scheduled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                        Err(e) => {
                            eprintln!("job {job}: {e}");
                            failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                    job += opts.submitters;
                }
            });
        }
    });
    let failed = failures.load(std::sync::atomic::Ordering::Relaxed);

    // One control connection for stats (and the optional shutdown).
    let code = match connect(&opts.addr) {
        Ok((mut reader, mut writer)) => {
            match roundtrip(&mut reader, &mut writer, "{\"verb\":\"stats\"}") {
                Ok(stats) => println!("stats: {}", stats.render()),
                Err(e) => eprintln!("stats: {e}"),
            }
            if opts.shutdown {
                match roundtrip(&mut reader, &mut writer, "{\"verb\":\"shutdown\"}") {
                    Ok(ack) if ack.get("ok").and_then(Json::as_bool) == Some(true) => {
                        println!("server acknowledged shutdown");
                        0
                    }
                    Ok(ack) => {
                        eprintln!("shutdown refused: {}", ack.render());
                        1
                    }
                    Err(e) => {
                        eprintln!("shutdown: {e}");
                        1
                    }
                }
            } else {
                0
            }
        }
        Err(e) => {
            eprintln!("control connection: {e}");
            1
        }
    };
    println!(
        "{} jobs over {} submitters: {} failed, {} co-scheduled",
        opts.jobs,
        opts.submitters,
        failed,
        co_scheduled.load(std::sync::atomic::Ordering::Relaxed)
    );
    std::process::exit(if failed == 0 { code } else { 1 });
}
