//! Client for `banded-svd serve` — the quickstart transcript in
//! `docs/service.md` and the CI smoke driver.
//!
//! Built entirely on the unified client API: each submitter thread owns
//! a [`RemoteClient`] (one TCP connection each — concurrent connections
//! are what feed the server's micro-batcher), streams a mixed-shape
//! mixed-precision load through [`Client::submit_wait`], and
//! sanity-checks every [`ReductionOutcome`]. All wire shaping lives in
//! `banded_svd::client::wire`; this example contains none. With
//! `--shutdown` it also stops the server — the CI smoke job asserts the
//! clean-shutdown path.
//!
//! ```text
//! cargo run --release --example serve_client -- \
//!     --addr 127.0.0.1:7070 --jobs 16 --submitters 4 --shutdown
//! ```

use banded_svd::client::{Client, ReductionOutcome, ReductionRequest, RemoteClient};
use banded_svd::scalar::ScalarKind;

struct Opts {
    addr: String,
    jobs: usize,
    submitters: usize,
    seed: u64,
    shutdown: bool,
}

fn parse_opts() -> Result<Opts, String> {
    let mut opts = Opts {
        addr: "127.0.0.1:7070".to_string(),
        jobs: 8,
        submitters: 4,
        seed: 42,
        shutdown: false,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        let take = |i: &mut usize| -> Result<String, String> {
            *i += 1;
            argv.get(*i).cloned().ok_or_else(|| format!("{} needs a value", argv[*i - 1]))
        };
        match argv[i].as_str() {
            "--addr" => opts.addr = take(&mut i)?,
            "--jobs" => opts.jobs = take(&mut i)?.parse().map_err(|e| format!("--jobs: {e}"))?,
            "--submitters" => {
                opts.submitters = take(&mut i)?.parse().map_err(|e| format!("--submitters: {e}"))?
            }
            "--seed" => opts.seed = take(&mut i)?.parse().map_err(|e| format!("--seed: {e}"))?,
            "--shutdown" => opts.shutdown = true,
            other => {
                return Err(format!(
                    "unknown option {other:?} \
                     (--addr --jobs --submitters --seed --shutdown)"
                ))
            }
        }
        i += 1;
    }
    opts.jobs = opts.jobs.max(1);
    opts.submitters = opts.submitters.clamp(1, opts.jobs);
    Ok(opts)
}

/// The cycling job mix: (n, bw, precision).
const SHAPES: [(usize, usize, ScalarKind); 4] = [
    (96, 8, ScalarKind::F64),
    (64, 6, ScalarKind::F32),
    (48, 5, ScalarKind::F64),
    (80, 10, ScalarKind::F32),
];

fn request_for(job: usize, seed: u64) -> ReductionRequest {
    let (n, bw, kind) = SHAPES[job % SHAPES.len()];
    ReductionRequest::new().random(n, bw, kind, seed.wrapping_add(job as u64))
}

fn check_outcome(outcome: &ReductionOutcome) -> Result<(usize, usize), String> {
    let p = outcome.problems.first().ok_or("empty outcome")?;
    if p.sv.len() != p.n {
        return Err(format!("{} singular values for n={}", p.sv.len(), p.n));
    }
    if p.sv.windows(2).any(|w| w[0] < w[1]) {
        return Err("singular values not descending".into());
    }
    if p.metrics.launches == 0 {
        return Err("no launches recorded".into());
    }
    Ok((p.n, p.batch_jobs))
}

fn main() {
    let opts = match parse_opts() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let failures = std::sync::atomic::AtomicUsize::new(0);
    let co_scheduled = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for submitter in 0..opts.submitters {
            let (opts, failures, co_scheduled) = (&opts, &failures, &co_scheduled);
            scope.spawn(move || {
                let client = match RemoteClient::connect(&opts.addr) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("submitter {submitter}: connect {}: {e}", opts.addr);
                        failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        return;
                    }
                };
                let mut job = submitter;
                while job < opts.jobs {
                    match client
                        .submit_wait(request_for(job, opts.seed))
                        .map_err(|e| e.to_string())
                        .and_then(|o| check_outcome(&o))
                    {
                        Ok((n, batch_jobs)) => {
                            println!("job {job}: n={n} ok (batch of {batch_jobs})");
                            if batch_jobs > 1 {
                                co_scheduled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                        Err(e) => {
                            eprintln!("job {job}: {e}");
                            failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                    job += opts.submitters;
                }
            });
        }
    });
    let failed = failures.load(std::sync::atomic::Ordering::Relaxed);

    // One control connection for stats (and the optional shutdown).
    let code = match RemoteClient::connect(&opts.addr) {
        Ok(control) => {
            match control.server_stats() {
                Ok(stats) => println!("stats: {}", stats.render()),
                Err(e) => eprintln!("stats: {e}"),
            }
            if opts.shutdown {
                match control.shutdown() {
                    Ok(()) => {
                        println!("server acknowledged shutdown");
                        0
                    }
                    Err(e) => {
                        eprintln!("shutdown: {e}");
                        1
                    }
                }
            } else {
                0
            }
        }
        Err(e) => {
            eprintln!("control connection: {e}");
            1
        }
    };
    println!(
        "{} jobs over {} submitters: {} failed, {} co-scheduled",
        opts.jobs,
        opts.submitters,
        failed,
        co_scheduled.load(std::sync::atomic::Ordering::Relaxed)
    );
    std::process::exit(if failed == 0 { code } else { 1 });
}
